//! The generation service: a worker thread running either the
//! continuous-batching scheduler (default) or the legacy lockstep group
//! protocol, plus a submit API used by both the TCP front-end and the
//! in-process benches.
//!
//! Continuous mode (DESIGN.md §Serving): the worker runs ONE decode
//! iteration at a time over the occupied rows of a per-request KV slot
//! arena. Finished requests leave the batch and free their slot
//! immediately; newly admitted requests (any prompt length) are
//! prefilled solo and join mid-flight. Admission is slot-granular
//! against the KV pool.
//!
//! Speculative mode (DESIGN.md §Speculative iterations): with
//! `ServerConfig.spec` set, each iteration becomes draft-and-verify. A
//! draft engine (same Arc-shared weights, an NBL-heavier plan — §5
//! self-speculation) keeps its own slot arena in lockstep with the
//! target's; gamma = W-1 batched draft steps propose tokens for every
//! occupied row, one width-W target pass verifies them, and each row
//! commits 1..=W tokens (rejected suffixes roll back via
//! `SlotArena::set_pos`, exactly the KvState protocol of spec/mod.rs).
//!
//! Prefix reuse (DESIGN.md §Prefix cache): with
//! `ServerConfig.prefix_cache_bytes` set, every admission — whole-prompt
//! and chunked, plain and speculative — first probes a radix tree of
//! prompt prefixes, adopts the longest cached KV snapshot into its slot,
//! and prefills only the uncovered suffix. Prefill publishes snapshots
//! back at snap-aligned boundaries (insert-on-miss), so the cache warms
//! itself under churn with no separate calibration pass. One tree entry
//! carries the target snapshot AND the draft's, so the two arenas enter
//! decode in lockstep exactly as with cold admission.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};

use crate::data::tokenizer::ByteTokenizer;
use crate::error::{Error, Result};
use crate::executor::engine::{Engine, RowDecode, RowSpecDecode};
use crate::kvcache::paged::{PagedEntry, PagedKv, PagedRun};
use crate::kvcache::prefix::{KvSnapshot, PrefixCache, PrefixValue};
use crate::kvcache::{
    kv_bytes, slot_bytes, take_row_state, KvLeaseOwned, KvPool, KvState, SlotArena,
};
use crate::nbl::plan::ModelPlan;
use crate::sampling::{argmax, Sampler};
use crate::server::api::{GenRequest, GenResponse, StreamToken};
use crate::server::batcher::{Batcher, Scheduler};
use crate::server::dispatch::{self, HostLane, HostWork, ReplicaStatus};
use crate::server::metrics::{MetricsHub, RequestTiming, Stopwatch};
use crate::server::trace::{SpanKind, TraceRecorder};
use crate::tensor::Tensor;
use crate::util::lock_unpoisoned;
use crate::util::timer::Timer;

/// Worker-loop scheduling protocol.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchMode {
    /// Iteration-level continuous batching over per-request KV slots
    /// (the default).
    Continuous,
    /// Legacy lockstep protocol: exact-length groups run
    /// prefill->decode to completion (the benches' baseline).
    ExactLength,
}

/// Self-speculative decoding for the continuous worker (paper §5 /
/// Table 6): the draft is the SAME weights under a cheaper plan, so no
/// second checkpoint is loaded.
#[derive(Debug, Clone)]
pub struct SpecConfig {
    /// Plan the draft engine runs (typically NBL-heavier than the
    /// target's — `Engine::with_plan` shares the weight buffers).
    pub draft_plan: ModelPlan,
    /// Verify width W: the target checks W tokens per row per iteration
    /// (gamma = W-1 draft proposals + the last committed token). Must be
    /// covered by the AOT `cached_lens` grid for the fast path; widths
    /// < 2 disable speculation.
    pub width: usize,
}

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub max_batch: usize,
    /// KV pool capacity in bytes (admission control).
    pub kv_capacity_bytes: usize,
    /// Optional stop token.
    pub eos: Option<u32>,
    /// Scheduling protocol for the async worker.
    pub mode: BatchMode,
    /// Speculative draft-and-verify iterations (Continuous mode only).
    pub spec: Option<SpecConfig>,
    /// Chunked prefill (DESIGN.md §Chunked prefill): admissions whose
    /// prompt exceeds this many tokens prefill as a sequence of
    /// cache-appending chunks, at most one chunk per decode iteration,
    /// so in-flight decode rows never stall behind a whole long prompt.
    /// Snapped onto the AOT prefill grid at serve time; 0 disables
    /// chunking (whole-prompt admission prefill — also the automatic
    /// fallback when the artifact set predates the chunk ops).
    pub prefill_chunk: usize,
    /// Prefix-aware KV reuse (DESIGN.md §Prefix cache): host-side byte
    /// budget for the radix-tree prompt cache. Admissions adopt the
    /// longest cached prefix and prefill only the uncovered suffix;
    /// prefill publishes snapshots back (insert-on-miss). 0 disables
    /// the cache (also the automatic fallback when the artifact set
    /// predates the cache-appending chunk ops).
    pub prefix_cache_bytes: usize,
    /// Snapshot granularity in tokens: snapshots land at multiples of
    /// this, aligned UP to a multiple of the serve-time chunk when
    /// chunking is on (so an adopted prefix re-enters the chunk ladder
    /// exactly where a cold admission would). 0 = auto: the chunk size,
    /// or 128 with chunking off.
    pub prefix_snap: usize,
    /// Paged KV admission (DESIGN.md §Paged KV): block size in tokens
    /// for the block-pool cache. Requests charge the KV pool
    /// block-by-block as their context grows (instead of a worst-case
    /// contiguous row at admission), warm prefix adoptions splice
    /// refcounted shared block runs at zero pool charge, and admission
    /// stalls preempt the latest-admitted slot instead of wedging. 0 =
    /// contiguous slot-granular admission (the legacy accounting).
    /// Continuous mode only.
    pub kv_block_tokens: usize,
    /// Flight-recorder ring capacity in events (DESIGN.md
    /// §Observability). 0 disables tracing entirely: every hook is a
    /// branch on a plain field — no clock read, no lock, no allocation
    /// on the hot path.
    pub trace_events: usize,
    /// Raw `RequestTiming` retention window for `MetricsHub::timings()`
    /// (0 = unbounded, for offline analysis runs). Summary percentiles
    /// come from the lifetime streaming histograms regardless.
    pub timing_retention: usize,
    /// Data-parallel replica count (DESIGN.md §Data parallelism).
    /// `> 1` spawns that many engine replicas over the SAME Arc-shared
    /// weights — each with its own iteration loop, slot arenas, paged
    /// accounting, and gauge/trace lane — behind a prefix-affinity
    /// dispatcher, all charging one shared KV byte ceiling. 1 (the
    /// default) runs the single-worker loop unchanged, byte-identical
    /// to the pre-replication server. Continuous mode only; the legacy
    /// exact-length worker ignores this.
    pub replicas: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            kv_capacity_bytes: 1 << 30,
            eos: None,
            mode: BatchMode::Continuous,
            spec: None,
            prefill_chunk: 128,
            prefix_cache_bytes: 0,
            prefix_snap: 0,
            kv_block_tokens: 0,
            trace_events: 0,
            timing_retention: crate::server::metrics::DEFAULT_TIMING_RETENTION,
            replicas: 1,
        }
    }
}

pub struct Server {
    pub engine: Arc<Engine>,
    pub config: ServerConfig,
    pub metrics: Arc<MetricsHub>,
    pub pool: Arc<KvPool>,
    /// Flight recorder (disabled ring when `trace_events == 0`).
    pub trace: Arc<TraceRecorder>,
}

impl Server {
    pub fn new(engine: Arc<Engine>, config: ServerConfig) -> Server {
        let pool = Arc::new(KvPool::new(config.kv_capacity_bytes));
        let trace = Arc::new(TraceRecorder::new(config.trace_events));
        Server {
            engine,
            metrics: Arc::new(MetricsHub::with_retention(config.timing_retention)),
            pool,
            trace,
            config,
        }
    }

    /// Synchronously serve one request (the paper's batch-1 protocol).
    pub fn generate_one(&self, req: &GenRequest) -> GenResponse {
        match self.run_group(std::slice::from_ref(req)) {
            Ok(mut v) => v.pop().unwrap_or_else(|| {
                error_response(req.id, Error::Serving("empty response group".into()))
            }),
            Err(e) => error_response(req.id, e),
        }
    }

    /// Serve a group of equal-length-prompt requests in lockstep — the
    /// legacy run-to-completion protocol, kept as the exact-length
    /// baseline the continuous scheduler is benchmarked against.
    pub fn run_group(&self, group: &[GenRequest]) -> Result<Vec<GenResponse>> {
        let watches = group.iter().map(|_| Stopwatch::new()).collect();
        self.run_group_timed(group, watches)
    }

    /// [`run_group`](Self::run_group) with caller-provided stopwatches.
    /// The async ExactLength worker starts them at SUBMISSION so TTFT
    /// includes scheduler queue wait — the same clock continuous mode
    /// uses. (Starting the clock at group formation under-reported the
    /// baseline's TTFT by the whole queue wait and skewed every bench
    /// comparison.)
    pub fn run_group_timed(
        &self,
        group: &[GenRequest],
        mut watches: Vec<Stopwatch>,
    ) -> Result<Vec<GenResponse>> {
        let n = group.len();
        if n == 0 {
            return Ok(vec![]);
        }
        if watches.len() != n {
            return Err(Error::Serving(format!(
                "run_group: {} stopwatches for {n} requests",
                watches.len()
            )));
        }
        let len = group[0].prompt.len();
        if group.iter().any(|r| r.prompt.len() != len) {
            return Err(Error::Serving("group prompts must share length".into()));
        }
        let cfg = self.engine.config();
        let bucket_b = self.engine.batch_bucket(n)?;
        let _lease = self.pool.reserve(kv_bytes(
            cfg,
            self.engine.plan.kv_layers(),
            bucket_b,
            cfg.max_ctx,
            4,
        ))?;

        let max_new: usize = group.iter().map(|r| r.max_new_tokens).max().unwrap_or(0);
        // the first token comes from prefill logits and the k-th decode
        // step writes cache slot len+k-1, so max_ctx - len + 1 tokens fit
        // (clamping to max_ctx - len dropped one generable token at the
        // context boundary)
        let budget = (cfg.max_ctx + 1).saturating_sub(len);
        let max_new = max_new.min(budget);

        let mut samplers: Vec<Sampler> =
            group.iter().map(|r| Sampler::new(r.params.clone())).collect();
        let mut outputs: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut done: Vec<bool> = group.iter().map(|r| r.max_new_tokens == 0).collect();

        // prefill + first token. The group runs one batched prefill, so
        // each request's attribution charges the full call (its TTFT
        // really did wait for the whole batch); queue time ended when
        // the group formed.
        for w in watches.iter_mut() {
            w.mark_admitted();
        }
        let prefill_timer = Timer::start();
        let mut ids = Vec::with_capacity(n * len);
        for r in group {
            ids.extend_from_slice(&r.prompt);
        }
        let pre = self.engine.prefill(&ids, n, len, None)?;
        let mut state = pre.state;
        let logits = self.engine.head(&pre.hidden)?;
        let prefill_s = prefill_timer.elapsed_s();
        for w in watches.iter_mut() {
            w.add_prefill(prefill_s);
        }
        let mut next: Vec<u32> = (0..n)
            .map(|b| samplers[b].sample(logits.at2(b, len - 1)))
            .collect();
        for b in 0..n {
            if !done[b] {
                watches[b].mark_token();
                outputs[b].push(next[b]);
                if Some(next[b]) == self.config.eos || outputs[b].len() >= group[b].max_new_tokens {
                    done[b] = true;
                }
            }
        }

        // lockstep decode
        for _step in 1..max_new {
            if done.iter().all(|&d| d) {
                break;
            }
            let logits = self.engine.decode(&mut state, &next, 1)?;
            for b in 0..n {
                if done[b] {
                    next[b] = 0; // keep feeding pad; output ignored
                    continue;
                }
                let tok = samplers[b].sample(logits.at2(b, 0));
                watches[b].mark_token();
                outputs[b].push(tok);
                next[b] = tok;
                if Some(tok) == self.config.eos || outputs[b].len() >= group[b].max_new_tokens {
                    done[b] = true;
                }
            }
        }

        // finalize
        let mut responses = Vec::with_capacity(n);
        for (b, (req, sw)) in group.iter().zip(watches.into_iter()).enumerate() {
            let mut timing = sw.finish(len, outputs[b].len());
            timing.deadline_met = deadline_met(req.deadline_ms, &timing);
            let resp = ok_response(req.id, std::mem::take(&mut outputs[b]), &timing);
            self.metrics.record(timing);
            responses.push(resp);
        }
        Ok(responses)
    }

    /// Spawn the worker loop; returns a handle for async submission.
    /// With `config.replicas > 1` (Continuous mode) the handle fronts a
    /// prefix-affinity dispatcher over N replicated loops instead of
    /// one worker — same submit/cancel/shutdown surface either way.
    pub fn spawn(self: Arc<Self>) -> ServerHandle {
        if self.config.mode == BatchMode::Continuous && self.config.replicas > 1 {
            return dispatch::spawn_replicated(self);
        }
        let (tx, rx): (Sender<Submission>, Receiver<Submission>) = channel();
        let server = self.clone();
        let join = std::thread::spawn(move || match server.config.mode {
            BatchMode::Continuous => run_continuous(&server, &rx),
            BatchMode::ExactLength => run_exact_length(&server, &rx),
        });
        ServerHandle { tx, join: Some(join) }
    }
}

// ------------------------------------------------------------ worker loops

/// A request resident in the decode group: one occupied arena slot.
struct ActiveSlot {
    req: GenRequest,
    sampler: Sampler,
    outputs: Vec<u32>,
    watch: Stopwatch,
    /// Token to feed at the next decode iteration (sampled last
    /// iteration, or from the prefill logits at admission).
    next: u32,
    /// max_new_tokens clamped to the context budget.
    effective_max: usize,
    /// Admission order: preemption evicts the HIGHEST sequence first
    /// (LIFO), so the oldest resident request always runs to completion
    /// — the livelock guard for preempt-under-pressure.
    seq: u64,
    /// Slot-granular KV reservation; returns to the pool when the
    /// request leaves the batch. None in paged mode, where the pool is
    /// charged block-by-block through [`PagedKv`] instead.
    _lease: Option<KvLeaseOwned>,
}

/// A request evicted from its slot under block-pool pressure
/// (DESIGN.md §Paged KV): its row caches are snapshotted host-side so
/// re-admission restores exactly where decode stopped (token parity
/// with an un-preempted run), at strict priority over fresh admissions.
struct PreemptedSlot {
    req: GenRequest,
    sampler: Sampler,
    outputs: Vec<u32>,
    watch: Stopwatch,
    next: u32,
    effective_max: usize,
    /// Original admission sequence, preserved across the round trip so
    /// a resumed request cannot become the next preemption victim of a
    /// younger one.
    seq: u64,
    /// Row cache at eviction (batch-1, target plan).
    target: KvState,
    /// Draft-arena row in lockstep (speculative mode only).
    draft: Option<KvState>,
}

/// Draft side of speculative serving: an engine over the same weights
/// with the draft plan, plus a slot arena kept in lockstep with the
/// target's (slot s of both arenas always belongs to the same request).
struct SpecState {
    engine: Engine,
    arena: Option<SlotArena>,
    width: usize,
}

/// Worker-local prefix-reuse state (DESIGN.md §Prefix cache): the radix
/// tree of prompt prefixes -> host KV snapshots, plus the snapshot
/// granularity resolved against the serve-time chunk. One tree entry
/// carries the target snapshot AND the draft's under speculation, so
/// the pair can never fall out of lockstep (the PR 4 chunk-lockstep
/// rule, applied to snapshots).
struct PrefixReuse {
    /// The tree lives behind a mutex because two other threads peek or
    /// mutate it: the replica's host lane runs deferred publications,
    /// and the dispatcher's prefix-affinity router peeks coverage when
    /// routing intake. Every access is a short per-operation lock —
    /// nothing holds the guard across engine calls or channel waits.
    cache: Arc<Mutex<PrefixCache>>,
    /// Snapshot positions are multiples of this many tokens.
    snap: usize,
}

impl PrefixReuse {
    /// Longest usable cached prefix of `prompt`, capped at len-1 so the
    /// suffix always yields first-token logits. The value is a legacy
    /// snapshot pair or a paged block-run entry, per the publish mode.
    fn probe(&mut self, prompt: &[u32]) -> Option<PrefixValue> {
        lock_unpoisoned(&self.cache).lookup(prompt, prompt.len().saturating_sub(1))
    }

    /// Stat-free coverage peek (the guard's slip test for queue heads
    /// waiting on the chunked machine — runs every iteration, so it
    /// must not touch LRU order or the probe counters).
    fn peek(&self, prompt: &[u32]) -> usize {
        lock_unpoisoned(&self.cache).covered(prompt, prompt.len().saturating_sub(1))
    }

    /// Resolve a probe hit: `covered > 0` means the snapshot was really
    /// restored into a slot; 0 means the admission fell back cold.
    fn resolve(&mut self, covered: usize) {
        let mut cache = lock_unpoisoned(&self.cache);
        if covered > 0 {
            cache.note_adopted(covered);
        } else {
            cache.note_fallback();
        }
    }
}

/// A multi-chunk admission in flight (DESIGN.md §Chunked prefill): the
/// prompt is prefilled one cache-appending chunk per scheduler
/// iteration instead of one whole blocking call, so decode rows stall
/// for at most one grid-width chunk at a time. The machine owns its
/// arena-row reservation (and the draft row under speculation) from the
/// first chunk, so later single-chunk admissions can never strand a
/// finished prefill without a slot. The TTFT stopwatch keeps running
/// from submission: the first token is marked only when the FINAL
/// chunk's logits are sampled, N iterations after admission started.
struct PendingPrefill {
    req: GenRequest,
    watch: Stopwatch,
    /// Slot-granular KV reservation, carried into the `ActiveSlot`
    /// (None in paged mode — the machine's blocks are attached in the
    /// block pool instead).
    lease: Option<KvLeaseOwned>,
    /// Reserved arena row (both arenas under speculation).
    slot: usize,
    /// Batch-1 cache being built chunk by chunk (`state.pos` == tokens
    /// prefilled so far), adopted into the reserved row when complete.
    state: KvState,
    /// Draft-engine cache built in lockstep (speculative mode only).
    draft_state: Option<KvState>,
    /// Prompt tokens prefilled so far.
    done: usize,
    /// Paged entry this machine warm-seeded from: its covered blocks
    /// become shared frames (`mark_shared`) at final adoption.
    warm_paged: Option<Arc<PagedEntry>>,
    /// Recorder timestamp when the machine started — the final chunk
    /// closes the `admit_chunked` span back to it (0 when tracing off).
    t0_us: u64,
}

/// Continuous-batching worker: one decode iteration per loop turn over
/// the occupied slots; admissions and departures happen between
/// iterations without restarting the batch. With speculation enabled an
/// iteration is draft-and-verify and commits up to W tokens per row.
fn run_continuous(server: &Arc<Server>, rx: &Receiver<Submission>) {
    run_replica(server, rx, ReplicaCtx::default());
}

/// One data-parallel replica's serving loop (DESIGN.md §Data
/// parallelism): the continuous worker parameterized by its lane id,
/// shared-cache handle, dispatcher status, and host lane. The default
/// context (`lane` 0, everything else off) IS the single-worker server
/// — `run_continuous` is just this with defaults, so N=1 behavior
/// cannot drift from the replicated path.
pub(crate) fn run_replica(server: &Arc<Server>, rx: &Receiver<Submission>, ctx: ReplicaCtx) {
    let mut il = IterationLoop::with_ctx(server, rx, ctx);
    while il.turn() {}
    il.shutdown();
}

/// Everything that distinguishes replica k from the plain single
/// worker. Built by [`dispatch::spawn_replicated`]; `Default` is the
/// single-worker identity.
#[derive(Default)]
pub(crate) struct ReplicaCtx {
    /// Gauge lane + worker-span tid this loop reports into.
    pub lane: usize,
    /// Replica-owned prefix cache, shared with the dispatcher for
    /// affinity peeks (None = build a private one from config, or
    /// prefix reuse is off).
    pub prefix: Option<Arc<Mutex<PrefixCache>>>,
    /// Dispatcher-visible inflight count (departs on terminal answer).
    pub status: Option<Arc<ReplicaStatus>>,
    /// Host-overlap lane: deferred sends and publications drain here
    /// while the device runs the next iteration.
    pub host: Option<HostLane>,
}

/// Per-worker output routing: terminal replies and streaming sinks,
/// plus — on a replica — the host lane that overlaps response sends,
/// frame emission, and prefix publication for iteration k with the
/// device compute of iteration k+1, and the dispatcher-visible
/// inflight count. All terminal paths answer through [`Self::respond`],
/// so the depart accounting and the frames-before-terminal ordering
/// (everything for one request rides one FIFO lane) hold everywhere.
struct Outbox {
    replies: HashMap<u64, Sender<GenResponse>>,
    sinks: HashMap<u64, Sender<StreamToken>>,
    host: Option<HostLane>,
    status: Option<Arc<ReplicaStatus>>,
}

impl Outbox {
    /// Answer (and forget) a request. No-op for unknown ids — exactly
    /// the old `respond` free-function contract.
    fn respond(&mut self, resp: GenResponse) {
        if let Some(tx) = self.replies.remove(&resp.id) {
            if let Some(st) = self.status.as_ref() {
                st.depart();
            }
            self.dispatch_host(HostWork::Respond(tx, resp));
        }
    }

    /// Forward one committed token on the request's streaming sink, if
    /// it has one. Send failures (receiver gone) are ignored: client
    /// disconnect is the front end's job to detect, and it answers
    /// with a cancel submission — the scheduler never blocks on a slow
    /// reader.
    fn emit(&mut self, id: u64, index: usize, token: u32) {
        if let Some(tx) = self.sinks.get(&id) {
            let tx = tx.clone();
            self.dispatch_host(HostWork::Emit(tx, StreamToken { id, index, token }));
        }
    }

    /// Publish crossed snapshot boundaries of a finished admission
    /// prefill. The states move INTO the work item (they are dead to
    /// the worker once adopted into the arena), so on a replica the
    /// whole multi-layer host copy runs on the host lane while the
    /// device starts the next iteration.
    fn publish(
        &mut self,
        px: &PrefixReuse,
        block_tokens: Option<usize>,
        prompt: &[u32],
        covered: usize,
        target: KvState,
        draft: Option<KvState>,
    ) {
        self.dispatch_host(HostWork::Publish {
            cache: px.cache.clone(),
            snap: px.snap,
            block_tokens,
            prompt: prompt.to_vec(),
            covered,
            target,
            draft,
        });
    }

    /// Defer to the host lane when one exists (running inline if its
    /// thread is gone), else run inline — the single-worker path.
    fn dispatch_host(&mut self, w: HostWork) {
        match self.host.as_mut() {
            Some(lane) => {
                if let Some(w) = lane.defer(w) {
                    dispatch::run_host_work(w);
                }
            }
            None => dispatch::run_host_work(w),
        }
    }

    /// Drop sinks whose request was already answered (the once-per-turn
    /// retain that keeps departure paths free of sink bookkeeping).
    fn prune_sinks(&mut self) {
        let replies = &self.replies;
        self.sinks.retain(|id, _| replies.contains_key(id));
    }

    /// Wait until every deferred item has been processed — the
    /// sequence-numbered handoff barrier. Called before the admission
    /// phase probes the prefix cache, so a replica always sees its own
    /// publications (the dispatcher's cross-replica peeks are
    /// stale-tolerant and never wait).
    fn quiesce(&self) {
        if let Some(lane) = self.host.as_ref() {
            lane.quiesce();
        }
    }

    /// Tear down the host lane: drains the queue, stops, joins. After
    /// this every send is inline (shutdown's terminal answers).
    fn finish(&mut self) {
        self.host.take();
    }
}

/// The continuous worker's complete per-iteration state, extracted from
/// the former ~1,500-line `run_continuous` body (the ROADMAP refactor
/// that unlocks preemption and future replication). Each scheduler turn
/// is a fixed phase sequence over these fields — intake, admission
/// (preempted resumes first), chunked prefill, starvation relief,
/// gauges, decode — instead of a dozen loop-local variables threaded
/// through free functions.
struct IterationLoop<'a> {
    server: &'a Arc<Server>,
    rx: &'a Receiver<Submission>,
    /// Draft engine + lockstep arena (speculative mode).
    spec: Option<SpecState>,
    /// Serve-time prefill chunk (0 = whole-prompt admission).
    chunk: usize,
    /// Radix-tree prompt-prefix cache (None = reuse off).
    prefix: Option<PrefixReuse>,
    /// Block-pool admission state (None = contiguous `slot_bytes`
    /// accounting). Born with the arena, like the draft arena.
    paged: Option<PagedKv>,
    /// Contiguous-mode worst-case bytes per resident request (target
    /// row + draft row under speculation).
    per_slot: usize,
    /// The in-flight chunked-prefill machine (at most one).
    pending: Option<PendingPrefill>,
    /// Preempted slots awaiting re-admission, oldest first. STRICT
    /// priority over fresh admissions: no new request admits while one
    /// waits, so eviction can never starve its victim (livelock guard).
    preempted: VecDeque<PreemptedSlot>,
    sched: Scheduler,
    /// Terminal replies + streaming sinks + (on a replica) the
    /// host-overlap lane and dispatcher status.
    out: Outbox,
    /// Submission-time stopwatches (TTFT includes queue wait).
    watches: HashMap<u64, Stopwatch>,
    /// Gauge lane and worker-span tid (replica index; 0 single-worker).
    lane: usize,
    arena: Option<SlotArena>,
    slots: Vec<Option<ActiveSlot>>,
    /// Rows that served an earlier request (slot-reuse accounting).
    row_used: Vec<bool>,
    /// Monotonic admission counter feeding `ActiveSlot::seq`.
    admit_seq: u64,
    /// Scheduler-turn counter: stamps every trace event with the
    /// iteration it happened in (`SpanRecord::iter`).
    turns: u64,
}

impl<'a> IterationLoop<'a> {
    fn with_ctx(
        server: &'a Arc<Server>,
        rx: &'a Receiver<Submission>,
        ctx: ReplicaCtx,
    ) -> IterationLoop<'a> {
        let ReplicaCtx { lane, prefix: shared_cache, status, host } = ctx;
        let engine = &server.engine;
        let spec: Option<SpecState> = match &server.config.spec {
            Some(sc) if sc.width >= 2 => {
                // snap the width onto the AOT cached-lens grid: an
                // off-grid width would otherwise fail EVERY iteration once
                // the fallback hits a non-bucket step
                let width = engine.snap_verify_width(sc.width);
                if width != sc.width {
                    eprintln!(
                        "server: verify width {} snapped to AOT bucket {width}",
                        sc.width
                    );
                }
                if width < 2 {
                    eprintln!("server: no verify bucket >= 2; serving without speculation");
                    None
                } else {
                    match engine.with_plan(sc.draft_plan.clone()) {
                        Ok(d) => Some(SpecState { engine: d, arena: None, width }),
                        Err(e) => {
                            // availability first: a bad draft plan degrades to
                            // plain continuous serving, not refused traffic
                            eprintln!(
                                "server: draft plan rejected ({e}); serving without speculation"
                            );
                            None
                        }
                    }
                }
            }
            _ => None,
        };
        // a resident request holds KV rows in BOTH arenas under speculation
        let per_slot = slot_bytes(engine.config(), &engine.plan)
            + spec
                .as_ref()
                .map_or(0, |sp| slot_bytes(engine.config(), &sp.engine.plan));
        // chunked prefill: snap the configured chunk size onto the AOT
        // prefill grid. 0 — or an artifact set that predates the
        // attn_prefill_chunk family — disables chunking, and admissions
        // prefill whole prompts (the fallback ladder's last rung; see
        // DESIGN.md §Chunked prefill).
        let chunk = match server.config.prefill_chunk {
            0 => 0,
            want => {
                let c = engine.snap_chunk_len(want);
                if c != want {
                    eprintln!("server: prefill chunk {want} snapped to AOT bucket {c}");
                }
                if engine.supports_chunked_prefill(1, c) {
                    c
                } else {
                    eprintln!(
                        "server: attn_prefill_chunk ops missing from the AOT grid; \
                         admissions prefill whole prompts (rebuild artifacts)"
                    );
                    0
                }
            }
        };
        // prefix-aware KV reuse (DESIGN.md §Prefix cache): probe-and-adopt
        // needs the cache-appending chunk ops to extend an adopted prefix,
        // so stale artifacts degrade to cold prefill, never to an error
        let prefix: Option<PrefixReuse> = match server.config.prefix_cache_bytes {
            0 => None,
            bytes if engine.supports_prefix_reuse() => {
                let want = match server.config.prefix_snap {
                    0 if chunk > 0 => chunk,
                    0 => 128,
                    w => w,
                };
                // chunk-align snapshot positions: an adopted prefix then
                // re-enters the chunk ladder exactly where a cold admission
                // would, so the ragged tail's padded bucket can never cross
                // the context boundary in a way cold admission could not
                let snap = if chunk > 0 { want.div_ceil(chunk) * chunk } else { want };
                // a replica adopts the dispatcher-shared handle (its
                // per-replica budget slice already applied); the single
                // worker builds a private tree from config
                let cache = shared_cache
                    .unwrap_or_else(|| Arc::new(Mutex::new(PrefixCache::new(bytes))));
                Some(PrefixReuse { cache, snap })
            }
            _ => {
                eprintln!(
                    "server: attn_prefill_chunk ops missing from the AOT grid; \
                     prefix cache disabled (rebuild artifacts)"
                );
                None
            }
        };
        IterationLoop {
            server,
            rx,
            spec,
            chunk,
            prefix,
            paged: None,
            per_slot,
            pending: None,
            preempted: VecDeque::new(),
            sched: Scheduler::new(),
            out: Outbox { replies: HashMap::new(), sinks: HashMap::new(), host, status },
            // stopwatches start at SUBMISSION so TTFT includes scheduler
            // queue wait (under load the queue is where latency lives)
            watches: HashMap::new(),
            lane,
            arena: None,
            slots: Vec::new(),
            row_used: Vec::new(),
            admit_seq: 0,
            turns: 0,
        }
    }

    /// One scheduler turn. Returns false on shutdown. Each phase is
    /// bracketed twice: a `Timer` feeding the always-on cumulative phase
    /// gauges (one `note_phases` hub lock per turn), and — only when the
    /// flight recorder is enabled — a worker-lane trace span. Intake
    /// includes the idle block waiting for the next submission.
    fn turn(&mut self) -> bool {
        let server = self.server;
        self.turns += 1;
        let iter = self.turns;
        // worker-lane spans carry the replica lane id in the `req`
        // field (rendered as the Chrome tid; see trace.rs), and every
        // gauge lands in this replica's lane of the hub
        let lane = self.lane as u64;
        let timer = Timer::start();
        let t0 = server.trace.begin();
        if !self.intake_phase() {
            return false;
        }
        server.trace.span(SpanKind::Intake, lane, iter, t0, 0);
        let intake_s = timer.elapsed_s();
        if !self.ensure_arena() {
            server.metrics.note_phases_at(self.lane, intake_s, 0.0, 0.0, 0.0, 0.0);
            return true;
        }
        let timer = Timer::start();
        let t0 = server.trace.begin();
        self.admission_phase();
        server.trace.span(SpanKind::Admission, lane, iter, t0, 0);
        let admission_s = timer.elapsed_s();
        let timer = Timer::start();
        let t0 = server.trace.begin();
        self.advance_chunked();
        server.trace.span(SpanKind::AdvanceChunked, lane, iter, t0, 0);
        let chunked_s = timer.elapsed_s();
        // starvation relief and deadline enforcement are scheduler
        // bookkeeping passes; their (tiny) cost is charged to the
        // observe phase
        let timer = Timer::start();
        let t0 = server.trace.begin();
        self.expire_inflight();
        self.starvation_phase();
        self.observe();
        server.trace.span(SpanKind::Observe, lane, iter, t0, 0);
        let observe_s = timer.elapsed_s();
        let occupied = self.slots.iter().filter(|s| s.is_some()).count() as u64;
        let timer = Timer::start();
        let t0 = server.trace.begin();
        self.decode_phase();
        if occupied > 0 {
            // skip the span on empty turns (chunk-only iterations):
            // zero-row "decode" spans would only churn the ring
            server.trace.span(SpanKind::Decode, lane, iter, t0, occupied);
        }
        let decode_s = timer.elapsed_s();
        server.metrics.note_phases_at(
            self.lane,
            intake_s,
            admission_s,
            chunked_s,
            observe_s,
            decode_s,
        );
        true
    }

    /// Intake: block when idle, poll between iterations (a pending
    /// chunked prefill or a preempted slot is work, not idleness).
    /// Cancellations drained here tear down before admission runs, and
    /// queued requests whose deadline already passed are shed — both
    /// halves of the ISSUE's intake-side lifecycle checks. Returns
    /// false on shutdown.
    fn intake_phase(&mut self) -> bool {
        let idle = self.slots.iter().all(|s| s.is_none())
            && self.sched.waiting() == 0
            && self.pending.is_none()
            && self.preempted.is_empty();
        let mut cancels: Vec<u64> = Vec::new();
        if idle {
            match self.rx.recv() {
                Ok(sub) => {
                    let tr = &self.server.trace;
                    if !intake(
                        sub,
                        &mut self.sched,
                        &mut self.out.replies,
                        &mut self.watches,
                        &mut self.out.sinks,
                        &mut cancels,
                        tr,
                    ) {
                        return false;
                    }
                }
                Err(_) => return false, // all senders dropped
            }
        }
        loop {
            match self.rx.try_recv() {
                Ok(sub) => {
                    let tr = &self.server.trace;
                    if !intake(
                        sub,
                        &mut self.sched,
                        &mut self.out.replies,
                        &mut self.watches,
                        &mut self.out.sinks,
                        &mut cancels,
                        tr,
                    ) {
                        return false;
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => return false,
            }
        }
        for id in cancels {
            self.cancel_request(id);
        }
        self.shed_expired_queued();
        true
    }

    /// Lazily size the arenas from the grid on first demand (the draft
    /// arena — and the paged block pool — are born together with the
    /// target's so slots stay in lockstep). Returns true when an arena
    /// exists to run the remaining phases against.
    fn ensure_arena(&mut self) -> bool {
        if self.arena.is_some() {
            return true;
        }
        if self.sched.waiting() == 0 {
            return false;
        }
        let server = self.server;
        let engine = &server.engine;
        let built = engine.new_arena(server.config.max_batch).and_then(|a| {
            let d = match &self.spec {
                Some(sp) => Some(sp.engine.new_arena(server.config.max_batch)?),
                None => None,
            };
            Ok((a, d))
        });
        match built {
            Ok((a, d)) => {
                self.slots = (0..a.bucket_batch).map(|_| None).collect();
                self.row_used = vec![false; a.bucket_batch];
                if let Some(sp) = self.spec.as_mut() {
                    sp.arena = d;
                }
                if server.config.kv_block_tokens > 0 {
                    let cfg = engine.config();
                    // clamp into (0, max_ctx]: the block is an admission
                    // accounting unit, not an AOT grid length
                    let bt = server.config.kv_block_tokens.clamp(1, cfg.max_ctx);
                    let t_bpb = kv_bytes(cfg, engine.plan.kv_layers(), 1, bt, 4);
                    let d_bpb = self
                        .spec
                        .as_ref()
                        .map_or(0, |sp| kv_bytes(cfg, sp.engine.plan.kv_layers(), 1, bt, 4));
                    self.paged = Some(PagedKv::new(
                        bt,
                        t_bpb,
                        d_bpb,
                        server.pool.clone(),
                        a.bucket_batch,
                    ));
                }
                self.arena = Some(a);
                true
            }
            Err(e) => {
                for r in self.sched.drain() {
                    self.watches.remove(&r.id);
                    self.out.respond(error_response(r.id, Error::msg(e.to_string())));
                }
                false
            }
        }
    }

    /// Admission: oldest-first into free slots while budget holds.
    /// Preempted slots resume FIRST, at strict priority. Prompts longer
    /// than one chunk enter the multi-iteration chunked-prefill machine
    /// (at most one in flight); single-chunk prompts admit whole,
    /// exactly as before chunking existed. In paged mode a request
    /// charges the pool only its prompt's blocks (growth comes later,
    /// block by block); in contiguous mode the worst-case row pair.
    fn admission_phase(&mut self) {
        // sequence-numbered handoff barrier: host work deferred during
        // the previous iteration — in particular prefix publications —
        // completes before this turn's cache probes, so a replica
        // always reads its own writes (hit-rate parity with the
        // single-worker loop; cross-replica peeks are stale-tolerant)
        if self.prefix.is_some() {
            self.out.quiesce();
        }
        self.resume_preempted();
        if !self.preempted.is_empty() {
            // strict resume priority: fresh admissions would consume the
            // very blocks the preempted slot is waiting for (livelock)
            return;
        }
        loop {
            if self.pending.is_some()
                && self.sched.head().is_none_or(|r| {
                    // the running machine owns the chunk budget: a head
                    // that still needs multi-chunk prefill waits for it
                    // (strict FIFO among multi-chunk prompts). The slip
                    // test uses the cache-UNCOVERED suffix, so a warm
                    // long prompt admits whole between chunks exactly
                    // like a genuinely short one — the stat-free peek
                    // keeps a waiting head from distorting LRU/stats.
                    let covered = self.prefix.as_ref().map_or(0, |px| px.peek(&r.prompt));
                    r.prompt.len().saturating_sub(covered) > self.chunk
                })
            {
                break;
            }
            let Some(arena) = self.arena.as_ref() else { break };
            let Some(slot) = arena.free_slot() else { break };
            let free = arena.free_slots();
            // per-request admission bytes: the paged pool charges the
            // prompt's blocks, the contiguous pool a worst-case row pair
            let head_bytes = match (&self.paged, self.sched.head()) {
                (Some(pk), Some(r)) => {
                    let d = self.spec.as_ref().map(|_| r.prompt.len());
                    pk.admit_bytes(r.prompt.len(), d)
                }
                _ => self.per_slot,
            };
            let Some(req) = self.sched.next_admission(free, &self.server.pool, head_bytes)
            else {
                break;
            };
            let lease = match self.paged.as_mut() {
                Some(pk) => {
                    let d = self.spec.as_ref().map(|_| req.prompt.len());
                    if pk.attach(slot, req.prompt.len(), d).is_err() {
                        // raced with an external reservation; retry next turn
                        self.sched.push_front(req);
                        break;
                    }
                    None
                }
                None => match KvPool::reserve_owned(&self.server.pool, self.per_slot) {
                    Ok(l) => Some(l),
                    Err(_) => {
                        // raced with an external reservation; retry next turn
                        self.sched.push_front(req);
                        break;
                    }
                },
            };
            let watch = take_watch(&mut self.watches, req.id);
            // queue span: submit → this dequeue, backdated off the watch
            self.server
                .trace
                .span_backdated(SpanKind::Queue, req.id, self.turns, watch.queue_s(), 0);
            // probe the prefix cache: the longest cached prefix decides
            // how much prefill is actually left, and THAT picks the
            // admission path (a long prompt whose suffix fits one chunk
            // admits whole, exactly like a genuinely short prompt)
            let hit = self.prefix.as_mut().and_then(|px| px.probe(&req.prompt));
            let covered = hit.as_ref().map_or(0, |v| v.tokens());
            // `pending.is_none()` is the guard's invariant restated: a
            // popped head only ever starts a machine when none runs
            // (overwriting one would leak its reserved row); if the two
            // ever disagreed, whole-prompt admit is the safe fallback
            if self.chunk > 0
                && self.pending.is_none()
                && req.prompt.len().saturating_sub(covered) > self.chunk
            {
                let slot_taken = slot;
                self.pending = self.start_chunked(slot, req, watch, lease, hit);
                if self.pending.is_none() {
                    // answered (or refused) without entering prefill:
                    // return the attached blocks
                    if let Some(pk) = self.paged.as_mut() {
                        pk.release(slot_taken);
                    }
                }
                continue;
            }
            self.admit(slot, req, watch, lease, hit);
            if self.slots.get(slot).is_none_or(|s| s.is_none()) {
                // the request finished on its prefill token or failed:
                // it never joined the batch, so its blocks go back
                if let Some(pk) = self.paged.as_mut() {
                    pk.release(slot);
                }
            }
        }
    }

    /// Re-admit preempted slots, oldest first, while free rows and
    /// block budget allow. A resumed request re-enters with its caches
    /// restored at the exact positions decode stopped at and its
    /// ORIGINAL admission sequence, so it cannot be victimized by a
    /// younger request's growth.
    fn resume_preempted(&mut self) {
        while let Some(front) = self.preempted.front() {
            let Some(pk) = self.paged.as_mut() else { break };
            let Some(arena) = self.arena.as_mut() else { break };
            let Some(slot) = arena.free_slot() else { break };
            let t_tokens = front.target.pos;
            let d_tokens = front.draft.as_ref().map(|d| d.pos);
            if !self.server.pool.would_fit(pk.admit_bytes(t_tokens, d_tokens)) {
                break;
            }
            if pk.attach(slot, t_tokens, d_tokens).is_err() {
                break;
            }
            let Some(mut p) = self.preempted.pop_front() else { break };
            // the park episode ends at un-parking regardless of whether
            // the adoption below succeeds (a failure errors the request)
            let parked_s = p.watch.park_end();
            self.server
                .trace
                .span_backdated(SpanKind::Park, p.req.id, self.turns, parked_s, 0);
            self.server.trace.instant(
                SpanKind::Resume,
                p.req.id,
                self.turns,
                p.outputs.len() as u64,
            );
            if let Err(e) = arena.adopt(slot, &p.target) {
                pk.release(slot);
                self.out.respond(error_response(p.req.id, e));
                continue;
            }
            if let Some(sp) = self.spec.as_mut() {
                let adopted = match (sp.arena.as_mut(), p.draft.as_ref()) {
                    (Some(da), Some(ds)) => da.adopt(slot, ds),
                    _ => Err(Error::Serving("draft state missing at resume".into())),
                };
                if let Err(e) = adopted {
                    arena.release(slot);
                    pk.release(slot);
                    self.out.respond(error_response(p.req.id, e));
                    continue;
                }
            }
            self.install_slot(
                slot,
                ActiveSlot {
                    req: p.req,
                    sampler: p.sampler,
                    outputs: p.outputs,
                    watch: p.watch,
                    next: p.next,
                    effective_max: p.effective_max,
                    seq: p.seq,
                    _lease: None,
                },
            );
        }
    }

    /// Install a newly admitted (or resumed) request into scheduler row
    /// `slot`, noting row reuse for the churn gauge. Bounds-checked: the
    /// slot index always comes from the arena's free list, which is
    /// sized in lockstep with `self.slots`.
    fn install_slot(&mut self, slot: usize, active: ActiveSlot) {
        let reused = self.row_used.get(slot).copied().unwrap_or(false);
        self.server.metrics.note_admission_at(self.lane, reused);
        if let Some(u) = self.row_used.get_mut(slot) {
            *u = true;
        }
        if let Some(entry) = self.slots.get_mut(slot) {
            *entry = Some(active);
        }
    }

    /// Free an active slot's arena row(s) — target AND draft under
    /// speculation — and its paged blocks, returning the departing
    /// request so the caller can decide the terminal answer. This is
    /// the same release sequence a natural EOS departure runs inside
    /// `decode_iteration`, factored out so cancellation and deadline
    /// expiry free resources through the identical path.
    fn release_active(&mut self, slot: usize) -> Option<ActiveSlot> {
        let a = self.slots.get_mut(slot).and_then(|s| s.take())?;
        if let Some(arena) = self.arena.as_mut() {
            arena.release(slot);
        }
        if let Some(sp) = self.spec.as_mut() {
            if let Some(da) = sp.arena.as_mut() {
                da.release(slot);
            }
        }
        if let Some(pk) = self.paged.as_mut() {
            pk.release(slot);
        }
        Some(a)
    }

    /// Tear down request `id` wherever it currently lives — queued,
    /// chunk-prefilling, parked, or decoding — and answer it with a
    /// typed [`Error::Cancelled`]. The freed slot re-enters the free
    /// list immediately, so a queued request admits into it on THIS
    /// turn's admission phase (the one-iteration reclaim guarantee).
    /// Unknown ids are a no-op: the cancel raced the final token and
    /// the client already has its answer.
    fn cancel_request(&mut self, id: u64) {
        let server = self.server;
        let iter = self.turns;
        // queued: drop from its tenant lane before it costs any prefill
        if let Some(r) = self.sched.remove(id) {
            self.watches.remove(&r.id);
            self.out.sinks.remove(&id);
            server.metrics.note_cancelled_at(self.lane);
            server.trace.instant(SpanKind::Cancel, id, iter, 0);
            self.out.respond(error_response(id, Error::Cancelled));
            return;
        }
        // mid-chunked-prefill: the machine owns reserved row(s) and, in
        // paged mode, attached blocks — all returned here
        if self.pending.as_ref().is_some_and(|p| p.req.id == id) {
            if let Some(p) = self.pending.take() {
                if let Some(arena) = self.arena.as_mut() {
                    release_reservation(arena, self.spec.as_mut(), self.paged.as_mut(), p.slot);
                }
                self.out.sinks.remove(&id);
                server.metrics.note_cancelled_at(self.lane);
                server.trace.instant(SpanKind::Cancel, id, iter, p.done as u64);
                self.out.respond(error_response(id, Error::Cancelled));
            }
            return;
        }
        // parked: holds no arena rows or blocks (preemption freed them);
        // the host-side snapshots just drop
        if let Some(i) = self.preempted.iter().position(|p| p.req.id == id) {
            if let Some(p) = self.preempted.remove(i) {
                self.out.sinks.remove(&id);
                server.metrics.note_cancelled_at(self.lane);
                server.trace.instant(SpanKind::Cancel, id, iter, p.outputs.len() as u64);
                self.out.respond(error_response(id, Error::Cancelled));
            }
            return;
        }
        // decoding: the same departure path EOS takes
        let slot = self
            .slots
            .iter()
            .position(|s| s.as_ref().is_some_and(|a| a.req.id == id));
        if let Some(a) = slot.and_then(|s| self.release_active(s)) {
            self.out.sinks.remove(&id);
            server.metrics.note_cancelled_at(self.lane);
            server.trace.instant(SpanKind::Cancel, id, iter, a.outputs.len() as u64);
            self.out.respond(error_response(id, Error::Cancelled));
        }
    }

    /// Intake-side deadline shed: a queued request whose deadline
    /// already passed can never meet it — drop it before it costs a
    /// prefill. Sheds count into deadline-SLO attainment (they ARE
    /// missed deadlines), unlike cancellations.
    fn shed_expired_queued(&mut self) {
        let watches = &self.watches;
        let shed = self.sched.shed_expired(|r| {
            r.deadline_ms.is_some_and(|d| {
                watches.get(&r.id).is_some_and(|w| w.elapsed_s() * 1e3 > d as f64)
            })
        });
        for r in shed {
            self.watches.remove(&r.id);
            self.out.sinks.remove(&r.id);
            self.server.metrics.note_shed_at(self.lane);
            self.server
                .trace
                .instant(SpanKind::Shed, r.id, self.turns, r.deadline_ms.unwrap_or(0));
            self.out.respond(error_response(r.id, Error::DeadlineExceeded));
        }
    }

    /// Observe-side deadline enforcement: preempt — with a typed error,
    /// through the normal release path — any in-flight request whose
    /// deadline has passed, whether it is decoding, chunk-prefilling,
    /// or parked. Expiring a decode frees its slot(s) for the next
    /// admission phase, so an expired straggler can no longer hold a
    /// row that a within-deadline request is queued for.
    fn expire_inflight(&mut self) {
        let iter = self.turns;
        let over = |deadline_ms: Option<u64>, w: &Stopwatch| {
            deadline_ms.is_some_and(|d| w.elapsed_s() * 1e3 > d as f64)
        };
        let hit: Vec<usize> = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(s, a)| {
                a.as_ref().filter(|a| over(a.req.deadline_ms, &a.watch)).map(|_| s)
            })
            .collect();
        for s in hit {
            if let Some(a) = self.release_active(s) {
                self.out.sinks.remove(&a.req.id);
                self.server.metrics.note_expired_at(self.lane);
                self.server
                    .trace
                    .instant(SpanKind::Expire, a.req.id, iter, a.outputs.len() as u64);
                self.out.respond(error_response(a.req.id, Error::DeadlineExceeded));
            }
        }
        if self.pending.as_ref().is_some_and(|p| over(p.req.deadline_ms, &p.watch)) {
            if let Some(p) = self.pending.take() {
                if let Some(arena) = self.arena.as_mut() {
                    release_reservation(arena, self.spec.as_mut(), self.paged.as_mut(), p.slot);
                }
                self.out.sinks.remove(&p.req.id);
                self.server.metrics.note_expired_at(self.lane);
                self.server.trace.instant(SpanKind::Expire, p.req.id, iter, p.done as u64);
                self.out.respond(error_response(p.req.id, Error::DeadlineExceeded));
            }
        }
        let mut keep = VecDeque::with_capacity(self.preempted.len());
        for p in self.preempted.drain(..) {
            if over(p.req.deadline_ms, &p.watch) {
                self.out.sinks.remove(&p.req.id);
                self.server.metrics.note_expired_at(self.lane);
                self.server
                    .trace
                    .instant(SpanKind::Expire, p.req.id, iter, p.outputs.len() as u64);
                self.out.respond(error_response(p.req.id, Error::DeadlineExceeded));
            } else {
                keep.push_back(p);
            }
        }
        self.preempted = keep;
    }

    /// A head that can never fit must not hang the queue (a pending
    /// machine holds budget and will free it; a nonempty resume backlog
    /// means decode departures are about to free blocks — wait).
    fn starvation_phase(&mut self) {
        if self.pending.is_some() || self.sched.waiting() == 0 {
            return;
        }
        if !self.preempted.is_empty() {
            // the resume backlog owns admission priority; if nothing is
            // even decoding, yield so the intake thread isn't starved
            if self.arena.as_ref().map_or(0, |a| a.occupancy()) == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            return;
        }
        if self.arena.as_ref().map_or(0, |a| a.occupancy()) > 0 {
            return;
        }
        let server = self.server;
        if let Some(pk) = self.paged.as_ref() {
            // paged mode: drain only heads whose FULL extent (prompt +
            // max_new_tokens, both arenas) exceeds an EMPTY pool —
            // anything smaller is merely waiting for blocks
            let max_ctx = server.engine.config().max_ctx;
            loop {
                let Some(r) = self.sched.head() else { break };
                let t = (r.prompt.len() + r.max_new_tokens).min(max_ctx);
                let d = self.spec.as_ref().map(|_| t);
                if pk.would_ever_fit(t, d) {
                    break;
                }
                let need = pk.admit_bytes(t, d);
                let cap = server.pool.capacity();
                let Some(req) = self.sched.next_admission(1, &server.pool, 0) else { break };
                self.watches.remove(&req.id);
                self.out.respond(error_response(
                    req.id,
                    Error::Serving(format!(
                        "KV pool exhausted: request needs {need} > capacity {cap}"
                    )),
                ));
            }
            if self.sched.waiting() > 0 && server.pool.in_use() > 0 {
                // an external lease holds the budget; yield briefly
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            return;
        }
        if !server.pool.would_fit(self.per_slot) {
            if server.pool.in_use() == 0 {
                let per_slot = self.per_slot;
                let cap = server.pool.capacity();
                for r in self.sched.drain() {
                    self.watches.remove(&r.id);
                    self.out.respond(error_response(
                        r.id,
                        Error::Serving(format!(
                            "KV pool exhausted: slot needs {per_slot} > capacity {cap}"
                        )),
                    ));
                }
            } else {
                // an external lease holds the budget; yield briefly
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }
    }

    /// Publish queue/pool/prefix/paged/tenant gauges for this
    /// iteration, and prune sinks whose request was already answered
    /// (terminal paths drop the reply; the sink follows here — one
    /// retain over a tiny map per turn keeps every departure path free
    /// of sink bookkeeping).
    fn observe(&mut self) {
        let server = self.server;
        self.out.prune_sinks();
        // distinct tenants with work anywhere in the system: queued,
        // decoding, chunk-prefilling, or parked
        let mut tenants: std::collections::HashSet<&str> = self.sched.tenant_names().collect();
        for a in self.slots.iter().flatten() {
            tenants.insert(a.req.tenant.as_str());
        }
        if let Some(p) = self.pending.as_ref() {
            tenants.insert(p.req.tenant.as_str());
        }
        for p in &self.preempted {
            tenants.insert(p.req.tenant.as_str());
        }
        server.metrics.observe_at(
            self.lane,
            self.sched.waiting(),
            server.pool.in_use(),
            server.pool.capacity(),
            tenants.len(),
        );
        if let Some(px) = self.prefix.as_ref() {
            let stats = lock_unpoisoned(&px.cache).stats();
            server.metrics.observe_prefix_at(self.lane, &stats);
        }
        if let Some(pk) = self.paged.as_ref() {
            server.metrics.observe_paged_at(self.lane, &pk.stats());
        }
    }

    /// One (possibly speculative) decode iteration over the occupied
    /// rows, after guaranteeing paged block headroom for its growth.
    fn decode_phase(&mut self) {
        if self.arena.as_ref().map_or(0, |a| a.occupancy()) == 0 {
            return;
        }
        // worst-case per-row growth this iteration: `width` target
        // tokens (speculative accept-all), `width - 1` draft tokens
        let width = self
            .spec
            .as_ref()
            .map_or(1, |sp| if sp.arena.is_some() { sp.width } else { 1 });
        self.ensure_paged_capacity(width);
        if self.arena.as_ref().map_or(0, |a| a.occupancy()) == 0 {
            return;
        }
        self.decode_iteration();
    }

    /// Guarantee every occupied row owns blocks for the coming
    /// iteration's worst-case growth. On block exhaustion the youngest
    /// admission (max `seq`) is preempted — its row caches snapshot to
    /// host, its blocks return to the pool — until the growth fits or
    /// the growing row is itself the victim (then it IS the youngest
    /// and simply waits preempted).
    fn ensure_paged_capacity(&mut self, width: usize) {
        if self.paged.is_none() {
            return;
        }
        let max_ctx = self.server.engine.config().max_ctx;
        let n = self.slots.len();
        for s in 0..n {
            'row: loop {
                if self.slots.get(s).is_none_or(|a| a.is_none()) {
                    break 'row;
                }
                let Some(arena) = self.arena.as_ref() else { return };
                let Some(pos) = arena.pos(s) else { break 'row };
                let t_need = (pos + width).min(max_ctx);
                let d_need = self.spec.as_ref().and_then(|sp| {
                    sp.arena.as_ref().and_then(|da| {
                        da.pos(s)
                            .map(|dp| (dp + width.saturating_sub(1)).min(da.max_ctx))
                    })
                });
                // `paged` was checked non-None at fn entry; a None here
                // (impossible) degrades to the preemption path below
                if self.paged.as_mut().is_some_and(|pk| pk.grow(s, t_need, d_need)) {
                    break 'row;
                }
                // out of blocks: evict the youngest admission (LIFO, so
                // the oldest resident always runs to completion)
                let victim = self
                    .slots
                    .iter()
                    .enumerate()
                    .filter_map(|(i, a)| a.as_ref().map(|a| (i, a.seq)))
                    .max_by_key(|&(_, seq)| seq);
                let Some((v, _)) = victim else { break 'row };
                self.preempt_slot(v);
                if v == s {
                    break 'row;
                }
            }
        }
    }

    /// Evict an active slot: snapshot its row cache(s) to host tensors,
    /// free the arena rows and paged blocks, and queue the request for
    /// re-admission at its original priority.
    fn preempt_slot(&mut self, slot: usize) {
        let server = self.server;
        let Some(arena) = self.arena.as_mut() else { return };
        let Some(mut a) = self.slots.get_mut(slot).and_then(|s| s.take()) else { return };
        let pos = arena.pos(slot).unwrap_or(0);
        let taken =
            take_row_state(&server.engine.plan, server.engine.config(), &arena.caches, slot, pos);
        arena.release(slot);
        let mut draft = None;
        let mut draft_required = false;
        if let Some(sp) = self.spec.as_mut() {
            if let Some(da) = sp.arena.as_mut() {
                draft_required = true;
                if let Some(dp) = da.pos(slot) {
                    if let Ok(ds) =
                        take_row_state(&sp.engine.plan, sp.engine.config(), &da.caches, slot, dp)
                    {
                        draft = Some(ds);
                    }
                }
                da.release(slot);
            }
        }
        if let Some(pk) = self.paged.as_mut() {
            pk.preempt(slot);
        }
        match taken {
            Ok(target) => {
                if draft_required && draft.is_none() {
                    let err = Error::Serving("draft snapshot failed at preemption".into());
                    self.out.respond(error_response(a.req.id, err));
                    return;
                }
                // park starts only once the snapshot actually succeeded
                // (a failed eviction errors the request instead)
                a.watch.park_begin();
                server
                    .trace
                    .instant(SpanKind::Preempt, a.req.id, self.turns, pos as u64);
                self.preempted.push_back(PreemptedSlot {
                    req: a.req,
                    sampler: a.sampler,
                    outputs: a.outputs,
                    watch: a.watch,
                    next: a.next,
                    effective_max: a.effective_max,
                    seq: a.seq,
                    target,
                    draft,
                });
            }
            Err(e) => {
                self.out.respond(error_response(a.req.id, e));
            }
        }
    }

    /// Shutdown: every queued, preempted, and in-flight request gets an
    /// answer (a silently dropped reply channel looks like a hung
    /// client).
    fn shutdown(&mut self) {
        if let Some(p) = self.pending.take() {
            self.out
                .respond(error_response(p.req.id, Error::Serving("server shut down".into())));
        }
        while let Some(p) = self.preempted.pop_front() {
            self.out
                .respond(error_response(p.req.id, Error::Serving("server shut down".into())));
        }
        for r in self.sched.drain() {
            let err = Error::Serving("server shut down".into());
            self.out.respond(error_response(r.id, err));
        }
        for slot in self.slots.iter_mut() {
            if let Some(a) = slot.take() {
                let err = Error::Serving("server shut down".into());
                self.out.respond(error_response(a.req.id, err));
            }
        }
        // leftover reply channels (e.g. requests answered nowhere above)
        // still go through the outbox, so the depart accounting and
        // FIFO ordering hold to the very last answer
        let ids: Vec<u64> = self.out.replies.keys().copied().collect();
        for id in ids {
            self.out.respond(error_response(id, Error::Serving("server shut down".into())));
        }
        // drain + stop + join the host lane: every deferred frame and
        // terminal answer is delivered before the worker thread exits
        self.out.finish();
    }
}

/// Prefill a prompt into a fresh batch-1 state, adopting a cached
/// prefix when one is usable. A paged block `run` materializes straight
/// into the state — no per-layer host snapshot expansion — while a
/// legacy `snap` restores through one expansion copy per kept layer
/// (gauged, so the bench can prove the paged path skips them). Either
/// way only the uncovered suffix prefills; the cold whole-prompt call
/// is the fallback when the prefix leaves no suffix, the padded suffix
/// bucket would cross the context boundary, or the adoption itself
/// fails. Returns (state, hidden, last real row of `hidden`, adopted
/// tokens; 0 adopted means the cold path ran).
fn prefill_with_prefix(
    engine: &Engine,
    prompt: &[u32],
    snap: Option<&KvSnapshot>,
    run: Option<&PagedRun>,
    metrics: &MetricsHub,
    lane: usize,
) -> Result<(KvState, Tensor, usize, usize)> {
    if let Some(r) = run {
        let p = r.tokens;
        if p > 0 && p < prompt.len() {
            let suffix = prompt.len() - p;
            let fits = engine
                .prefill_bucket(suffix)
                .is_ok_and(|tb| p + tb <= engine.config().max_ctx);
            if fits {
                // same accelerator-not-dependency rule as the snapshot
                // path: any failure falls through to cold prefill
                if let Ok(mut state) = r.materialize(&engine.plan, engine.config()) {
                    if let Ok(hidden) = engine.prefill_suffix(&mut state, &prompt[p..]) {
                        return Ok((state, hidden, suffix - 1, p));
                    }
                }
            }
        }
    }
    if let Some(s) = snap {
        let p = s.pos;
        if p > 0 && p < prompt.len() {
            let suffix = prompt.len() - p;
            let fits = engine
                .prefill_bucket(suffix)
                .is_ok_and(|tb| p + tb <= engine.config().max_ctx);
            if fits {
                // the cache is an accelerator, never a correctness
                // dependency: a failed restore or suffix prefill falls
                // through to the cold whole-prompt call below instead
                // of failing a request cold serving could answer
                if let Ok(mut state) = s.restore_state(&engine.plan, engine.config()) {
                    // the restore just expanded one host copy per kept
                    // layer — exactly the copies a paged splice avoids
                    metrics.note_prefix_expand_at(lane, engine.plan.kv_layers());
                    if let Ok(hidden) = engine.prefill_suffix(&mut state, &prompt[p..]) {
                        return Ok((state, hidden, suffix - 1, p));
                    }
                }
            }
        }
    }
    let pre = engine.prefill(prompt, 1, prompt.len(), None)?;
    Ok((pre.state, pre.hidden, prompt.len() - 1, 0))
}

/// Insert-on-miss snapshot publication: every snap-aligned boundary the
/// prefill just crossed in (covered, state.pos] becomes a reusable
/// prefix (target + draft snapshots in ONE entry under speculation, so
/// eviction can never separate them). Failures are swallowed — the
/// cache is an accelerator, never a correctness dependency.
fn publish_prefix_snapshots(
    cache: &Mutex<PrefixCache>,
    snap: usize,
    prompt: &[u32],
    covered: usize,
    target: &KvState,
    draft: Option<&KvState>,
) {
    let top = target.pos.min(prompt.len());
    let mut p = (covered / snap + 1) * snap;
    while p <= top {
        // check-and-touch FIRST: a snapshot is a multi-layer host copy
        // of the whole covered prefix, far too expensive to build just
        // for insert's dedup to throw away on every repeated prompt.
        // The lock is per-operation: the host copies below run with the
        // tree unlocked, so probes on other threads never wait on them.
        {
            let mut c = lock_unpoisoned(cache);
            if c.touch(&prompt[..p]) {
                c.note_publish_skip();
                p += snap;
                continue;
            }
        }
        let Ok(t) = KvSnapshot::from_state(target, p) else { return };
        let mut snaps = vec![t];
        if let Some(d) = draft {
            let Ok(ds) = KvSnapshot::from_state(d, p) else { return };
            snaps.push(ds);
        }
        if !lock_unpoisoned(cache).insert(&prompt[..p], snaps) {
            // capacity refusal (dedup was already handled by touch):
            // every later boundary is strictly larger and equally
            // doomed, so stop paying the host copies for them
            return;
        }
        p += snap;
    }
}

/// Publication dispatcher: refcounted block runs when the server runs a
/// block pool (`block_tokens` set), legacy whole-prefix snapshots
/// otherwise. Takes the raw cache handle + snap so it can run either
/// inline on the worker or deferred on a replica's host lane.
pub(crate) fn publish_prefix(
    cache: &Mutex<PrefixCache>,
    snap: usize,
    block_tokens: Option<usize>,
    prompt: &[u32],
    covered: usize,
    target: &KvState,
    draft: Option<&KvState>,
) {
    match block_tokens {
        Some(bt) => publish_prefix_paged(cache, snap, bt, prompt, covered, target, draft),
        None => publish_prefix_snapshots(cache, snap, prompt, covered, target, draft),
    }
}

/// Paged publication: each crossed snap-aligned boundary becomes a
/// refcounted block run. Capture is INCREMENTAL — full blocks already
/// resident under the longest cached ancestor are Arc-cloned, never
/// re-copied, and the cache budget is charged only the genuinely new
/// bytes — so republishing a growing prefix costs one partial tail
/// block, not the whole prefix again.
#[allow(clippy::too_many_arguments)]
fn publish_prefix_paged(
    cache: &Mutex<PrefixCache>,
    snap: usize,
    block_tokens: usize,
    prompt: &[u32],
    covered: usize,
    target: &KvState,
    draft: Option<&KvState>,
) {
    let top = target.pos.min(prompt.len());
    let mut p = (covered / snap + 1) * snap;
    while p <= top {
        // per-operation locks, same as the snapshot path: capture runs
        // with the tree unlocked
        let reuse = {
            let mut c = lock_unpoisoned(cache);
            if c.touch(&prompt[..p]) {
                // the covered block run is already resident: adopters
                // splice it zero-copy, so rebuilding it is pure waste
                c.note_publish_skip();
                p += snap;
                continue;
            }
            c.peek_value(&prompt[..p], p).and_then(|v| v.paged().cloned())
        };
        let Ok((trun, tnew)) =
            PagedRun::capture(target, p, block_tokens, reuse.as_ref().map(|e| &e.target))
        else {
            return;
        };
        let mut new_bytes = tnew;
        let mut drun = None;
        if let Some(d) = draft {
            let prev = reuse.as_ref().and_then(|e| e.draft.as_ref());
            let Ok((dr, dnew)) = PagedRun::capture(d, p, block_tokens, prev) else { return };
            new_bytes += dnew;
            drun = Some(dr);
        }
        let entry = Arc::new(PagedEntry { tokens: p, target: trun, draft: drun });
        if !lock_unpoisoned(cache).insert_paged(&prompt[..p], entry, new_bytes) {
            // capacity refusal: every later boundary is strictly larger
            // and equally doomed
            return;
        }
        p += snap;
    }
}

impl<'a> IterationLoop<'a> {
    /// Prefill a newly admitted request whose uncovered suffix fits ONE
    /// chunk, sample its first token, and (unless it already finished)
    /// migrate its cache into arena row `slot` — of the target arena
    /// AND, under speculation, the draft arena. A prefix-cache hit
    /// adopts either a paged block run (zero-copy splice) or a legacy
    /// snapshot restore and prefills only the suffix; either way the
    /// crossed snapshot boundaries are published back. This still runs
    /// on the worker thread while the iteration loop holds, but the
    /// stall is bounded by one chunk of real prefill; prompts with
    /// longer uncovered suffixes go through [`Self::start_chunked`] /
    /// [`Self::advance_chunked`] instead.
    fn admit(
        &mut self,
        slot: usize,
        req: GenRequest,
        mut watch: Stopwatch,
        lease: Option<KvLeaseOwned>,
        hit: Option<PrefixValue>,
    ) {
        self.admit_seq += 1;
        let seq = self.admit_seq;
        let iter = self.turns;
        let block_tokens = self.paged.as_ref().map(|pk| pk.block_tokens());
        let server = self.server;
        let admit_t0 = server.trace.begin();
        let Some(arena) = self.arena.as_mut() else {
            let err = Error::Serving("arena missing at admission".into());
            self.out.respond(error_response(req.id, err));
            return;
        };
        let mut spec = self.spec.as_mut();
        let mut prefix = self.prefix.as_mut();
        let out = &mut self.out;
        let engine = &server.engine;
        let cfg = engine.config();
        let len = req.prompt.len();
        if req.max_new_tokens == 0 {
            let timing = watch.finish(len, 0);
            out.respond(ok_response(req.id, Vec::new(), &timing));
            return;
        }
        let tsnap = hit.as_ref().and_then(|v| v.snaps()).and_then(|s| s.first());
        let trun = hit.as_ref().and_then(|v| v.paged()).map(|e| &e.target);
        let prefill_timer = Timer::start();
        let (state, hidden, col, covered) =
            match prefill_with_prefix(engine, &req.prompt, tsnap, trun, &server.metrics, self.lane)
            {
                Ok(t) => t,
                Err(e) => {
                    out.respond(error_response(req.id, e));
                    return;
                }
            };
        // pre-first-token prefill compute (warm restore + suffix, or the
        // cold whole-prompt call) — the `prefill_s` attribution slice
        watch.add_prefill(prefill_timer.elapsed_s());
        // hit accounting at ADOPTION time, not probe time: a hit whose
        // suffix bucket could not fit fell back cold and must count as a
        // miss, or the hit-rate gauge stays green while adoptions fail
        if hit.is_some() {
            if let Some(px) = prefix.as_deref_mut() {
                px.resolve(covered);
            }
        }
        let logits = match engine.head(&hidden) {
            Ok(l) => l,
            Err(e) => {
                out.respond(error_response(req.id, e));
                return;
            }
        };
        let mut sampler = Sampler::new(req.params.clone());
        let first = sampler.sample(logits.at2(0, col));
        watch.mark_token();
        out.emit(req.id, 0, first);
        let outputs = vec![first];
        // the prefill token is free and the k-th decode step writes cache
        // slot len+k-1, so max_ctx - len + 1 tokens fit in the context
        let effective_max = req
            .max_new_tokens
            .min((cfg.max_ctx + 1).saturating_sub(len))
            .max(1);
        if Some(first) == server.config.eos || outputs.len() >= effective_max {
            // finished on the prefill token: never occupies a slot. The
            // prefill still publishes in plain mode; under speculation no
            // draft state exists yet, and a target-only entry would break
            // the pair-lockstep invariant, so spec skips it.
            if spec.is_none() {
                if let Some(px) = prefix {
                    // the state is dead to the worker here — it moves
                    // into the deferred publication
                    out.publish(px, block_tokens, &req.prompt, covered, state, None);
                }
            }
            let kind = if covered > 0 { SpanKind::AdmitWarm } else { SpanKind::AdmitCold };
            server.trace.span(kind, req.id, iter, admit_t0, covered as u64);
            let mut timing = watch.finish(len, outputs.len());
            timing.deadline_met = deadline_met(req.deadline_ms, &timing);
            server.trace.instant(SpanKind::Finish, req.id, iter, outputs.len() as u64);
            let resp = ok_response(req.id, outputs, &timing);
            server.metrics.record(timing);
            out.respond(resp);
            return;
        }
        // draft prefill BEFORE any adoption, so a draft failure leaves no
        // half-adopted arena row behind
        let mut draft_state: Option<KvState> = None;
        if let Some(sp) = spec.as_deref() {
            let dsnap = hit.as_ref().and_then(|v| v.snaps()).and_then(|s| s.get(1));
            let drun = hit.as_ref().and_then(|v| v.paged()).and_then(|e| e.draft.as_ref());
            match prefill_with_prefix(
                &sp.engine,
                &req.prompt,
                dsnap,
                drun,
                &server.metrics,
                self.lane,
            ) {
                Ok((ds, _, _, _)) => draft_state = Some(ds),
                Err(e) => {
                    out.respond(error_response(req.id, e));
                    return;
                }
            }
        }
        if let Err(e) = arena.adopt(slot, &state) {
            out.respond(error_response(req.id, e));
            return;
        }
        if let Some(sp) = spec {
            // lockstep adoption into the SAME slot index
            let adopted = match (sp.arena.as_mut(), draft_state.as_ref()) {
                (Some(da), Some(ds)) => da.adopt(slot, ds),
                _ => Err(Error::Serving("draft arena missing at admission".into())),
            };
            if let Err(e) = adopted {
                arena.release(slot);
                out.respond(error_response(req.id, e));
                return;
            }
        }
        // graduate the adopted prefix to shared frames: its full blocks
        // are refcounted cache residents charging this slot ZERO pool
        // bytes, and only the partial tail keeps a private (CoW) frame
        if covered > 0 {
            if let (Some(pk), Some(entry)) =
                (self.paged.as_mut(), hit.as_ref().and_then(|v| v.paged()))
            {
                pk.mark_shared(slot, entry);
            }
        }
        if let Some(px) = prefix {
            // both states were just adopted (copied) into the arenas, so
            // they move into the deferred publication: on a replica the
            // multi-layer snapshot copies overlap the next device
            // iteration instead of stalling this one
            out.publish(px, block_tokens, &req.prompt, covered, state, draft_state);
        }
        let kind = if covered > 0 { SpanKind::AdmitWarm } else { SpanKind::AdmitCold };
        server.trace.span(kind, req.id, iter, admit_t0, covered as u64);
        self.install_slot(
            slot,
            ActiveSlot {
                req,
                sampler,
                outputs,
                watch,
                next: first,
                effective_max,
                seq,
                _lease: lease,
            },
        );
    }

    /// Begin a multi-chunk admission (DESIGN.md §Chunked prefill):
    /// answer zero-token requests immediately, otherwise reserve arena
    /// row `slot` (in both arenas under speculation) and return the
    /// state machine that [`Self::advance_chunked`] drives one chunk
    /// per iteration. A prefix-cache hit seeds the machine mid-prompt —
    /// a paged block run materializes, a legacy snapshot restores — and
    /// chunking starts at the covered position (the target and draft
    /// adopt atomically — a failed draft restore must not leave the
    /// pair out of lockstep, so both restart cold). Returns None if the
    /// request was answered (or the reservation failed) instead of
    /// entering prefill.
    fn start_chunked(
        &mut self,
        slot: usize,
        req: GenRequest,
        mut watch: Stopwatch,
        lease: Option<KvLeaseOwned>,
        hit: Option<PrefixValue>,
    ) -> Option<PendingPrefill> {
        let chunk = self.chunk;
        let server = self.server;
        let t0_us = server.trace.begin();
        let Some(arena) = self.arena.as_mut() else {
            let err = Error::Serving("arena missing at admission".into());
            self.out.respond(error_response(req.id, err));
            return None;
        };
        let mut spec = self.spec.as_mut();
        let prefix = self.prefix.as_mut();
        let out = &mut self.out;
        let engine = &server.engine;
        let cfg = engine.config();
        if req.max_new_tokens == 0 {
            let timing = watch.finish(req.prompt.len(), 0);
            out.respond(ok_response(req.id, Vec::new(), &timing));
            return None;
        }
        if let Err(e) = arena.reserve(slot) {
            out.respond(error_response(req.id, e));
            return None;
        }
        if let Some(sp) = spec.as_deref_mut() {
            let reserved = sp
                .arena
                .as_mut()
                .ok_or_else(|| Error::Serving("draft arena missing at admission".into()))
                .and_then(|da| da.reserve(slot));
            if let Err(e) = reserved {
                arena.release(slot);
                out.respond(error_response(req.id, e));
                return None;
            }
        }
        let draft_plan = spec.as_deref().map(|sp| &sp.engine.plan);
        let mut done = 0usize;
        let mut state = KvState::empty(&engine.plan, cfg, 1, 1);
        let mut draft_state = draft_plan.map(|dp| KvState::empty(dp, cfg, 1, 1));
        let mut warm_paged = None;
        let warm_timer = Timer::start();
        match hit.as_ref() {
            Some(PrefixValue::Snaps(snaps)) => {
                let p = snaps[0].pos;
                // chunk-aligned snapshot positions re-enter the chunk
                // ladder exactly where a cold machine would stand after
                // p tokens, so every later chunk (and the ragged tail)
                // stays on the grid
                let usable = p > 0
                    && p < req.prompt.len()
                    && p % chunk == 0
                    && (draft_plan.is_none() || snaps.len() > 1);
                if usable {
                    let warm = snaps[0].restore_state(&engine.plan, cfg).ok().and_then(|t| {
                        match draft_plan {
                            None => Some((t, None)),
                            Some(dp) => {
                                snaps[1].restore_state(dp, cfg).ok().map(|d| (t, Some(d)))
                            }
                        }
                    });
                    if let Some((t, d)) = warm {
                        server.metrics.note_prefix_expand_at(self.lane, engine.plan.kv_layers());
                        if let (Some(dp), true) = (draft_plan, d.is_some()) {
                            server.metrics.note_prefix_expand_at(self.lane, dp.kv_layers());
                        }
                        done = p;
                        state = t;
                        if d.is_some() {
                            draft_state = d;
                        }
                    }
                }
            }
            Some(PrefixValue::Paged(entry)) => {
                let p = entry.tokens;
                // same chunk-grid rule as snapshots; the run must also
                // carry a draft side under speculation (pair lockstep)
                let usable = p > 0
                    && p < req.prompt.len()
                    && p % chunk == 0
                    && (draft_plan.is_none() || entry.draft.is_some());
                if usable {
                    let warm = entry.target.materialize(&engine.plan, cfg).ok().and_then(|t| {
                        match draft_plan {
                            None => Some((t, None)),
                            Some(dp) => entry
                                .draft
                                .as_ref()
                                .and_then(|dr| dr.materialize(dp, cfg).ok())
                                .map(|d| (t, Some(d))),
                        }
                    });
                    if let Some((t, d)) = warm {
                        done = p;
                        state = t;
                        if d.is_some() {
                            draft_state = d;
                        }
                        // remembered so final adoption can graduate the
                        // covered blocks to shared frames
                        warm_paged = Some(entry.clone());
                    }
                }
            }
            None => {}
        }
        if done > 0 {
            // a warm seed's restore/materialize is prefill work the
            // machine no longer has to do chunk by chunk — charge it
            watch.add_prefill(warm_timer.elapsed_s());
        }
        // same adoption-time accounting as `admit`: an unusable hit (bad
        // alignment, failed restore) seeded a cold machine = a miss
        if hit.is_some() {
            if let Some(px) = prefix {
                px.resolve(done);
            }
        }
        Some(PendingPrefill {
            state,
            draft_state,
            req,
            watch,
            lease,
            slot,
            done,
            warm_paged,
            t0_us,
        })
    }

    /// Run ONE chunk of the pending admission through the target — and,
    /// in lockstep, the draft — engine. On the final chunk: sample the
    /// first token from the chunk's last real row, mark TTFT on the
    /// stopwatch that has been running since submission (the bugfix
    /// invariant: N chunk iterations of queue-adjacent prefill still
    /// count into TTFT), and adopt the built caches into the reserved
    /// slot(s). Snapshot boundaries the chunk crossed publish into the
    /// prefix cache as they happen — the "taken at chunk boundaries"
    /// half of insert-on-miss.
    fn advance_chunked(&mut self) {
        let chunk = self.chunk;
        let block_tokens = self.paged.as_ref().map(|pk| pk.block_tokens());
        let server = self.server;
        let engine = &server.engine;
        let Some(arena) = self.arena.as_mut() else { return };
        let Some(p) = self.pending.as_mut() else { return };
        let mut spec = self.spec.as_mut();
        let iter = self.turns;
        let len = p.req.prompt.len();
        let step = chunk.min(len - p.done);
        let ids = &p.req.prompt[p.done..p.done + step];
        let timer = Timer::start();
        let c0 = server.trace.begin();
        let mut run = engine.prefill_chunk(&mut p.state, ids, step);
        if run.is_ok() {
            if let Some(sp) = spec.as_mut() {
                // draft lockstep: the draft cache must cover exactly the
                // same prefix, or the first draft-and-verify round would
                // propose from a stale context
                run = match p.draft_state.as_mut() {
                    Some(ds) => sp.engine.prefill_chunk(ds, ids, step).and(run),
                    None => Err(Error::Serving("draft state missing mid-prefill".into())),
                };
            }
        }
        // every chunk that runs while decode rows are live stalls the
        // whole group for its duration — the interference gauge
        // chunking bounds
        server.metrics.note_prefill_chunk_at(self.lane, arena.occupancy() > 0, timer.elapsed_s());
        server.trace.span(SpanKind::PrefillChunk, p.req.id, iter, c0, step as u64);
        // each chunk is pre-first-token prefill compute for THIS request
        p.watch.add_prefill(timer.elapsed_s());
        let hidden = match run {
            Ok(h) => h,
            Err(e) => {
                let Some(p) = self.pending.take() else { return };
                release_reservation(arena, spec.as_deref_mut(), self.paged.as_mut(), p.slot);
                server.trace.instant(SpanKind::ErrorEvt, p.req.id, iter, 0);
                self.out.respond(error_response(p.req.id, e));
                return;
            }
        };
        p.done += step;
        if let Some(px) = self.prefix.as_ref() {
            // inline (not deferred): the machine still owns and keeps
            // appending to `p.state`, so the boundary snapshot cannot
            // move off-thread — chunk publications stay on the worker
            let before = p.done - step;
            publish_prefix(
                &px.cache,
                px.snap,
                block_tokens,
                &p.req.prompt,
                before,
                &p.state,
                p.draft_state.as_ref(),
            );
        }
        if p.done < len {
            return;
        }

        // ---- final chunk: first token, then adoption into the
        // reserved row
        let Some(p) = self.pending.take() else { return };
        self.admit_seq += 1;
        let seq = self.admit_seq;
        // the machine completed its prefill — counted here, not at
        // adoption: a max-context prompt whose budget is exactly the
        // prefill token (effective_max 1) still chunked its way in
        server.metrics.note_chunked_admission_at(self.lane);
        // the whole machine's lifetime, start_chunked → final chunk
        server.trace.span(SpanKind::AdmitChunked, p.req.id, iter, p.t0_us, len as u64);
        let logits = match engine.head(&hidden) {
            Ok(l) => l,
            Err(e) => {
                release_reservation(arena, spec.as_deref_mut(), self.paged.as_mut(), p.slot);
                server.trace.instant(SpanKind::ErrorEvt, p.req.id, iter, 0);
                self.out.respond(error_response(p.req.id, e));
                return;
            }
        };
        let mut watch = p.watch;
        let mut sampler = Sampler::new(p.req.params.clone());
        let first = sampler.sample(logits.at2(0, step - 1));
        watch.mark_token();
        self.out.emit(p.req.id, 0, first);
        let outputs = vec![first];
        let cfg = engine.config();
        // same budget as whole-prompt admission: the prefill token is
        // free and the k-th decode write lands at len + k - 1
        let effective_max = p
            .req
            .max_new_tokens
            .min((cfg.max_ctx + 1).saturating_sub(len))
            .max(1);
        if Some(first) == server.config.eos || outputs.len() >= effective_max {
            // finished on the prefill token: the reserved row never joins
            release_reservation(arena, spec.as_deref_mut(), self.paged.as_mut(), p.slot);
            let mut timing = watch.finish(len, outputs.len());
            timing.deadline_met = deadline_met(p.req.deadline_ms, &timing);
            server.trace.instant(SpanKind::Finish, p.req.id, iter, outputs.len() as u64);
            let resp = ok_response(p.req.id, outputs, &timing);
            server.metrics.record(timing);
            self.out.respond(resp);
            return;
        }
        if let Err(e) = arena.adopt(p.slot, &p.state) {
            release_reservation(arena, spec.as_deref_mut(), self.paged.as_mut(), p.slot);
            self.out.respond(error_response(p.req.id, e));
            return;
        }
        if let Some(sp) = spec.as_mut() {
            let adopted = match (sp.arena.as_mut(), p.draft_state.as_ref()) {
                (Some(da), Some(ds)) => da.adopt(p.slot, ds),
                _ => Err(Error::Serving("draft arena missing at adoption".into())),
            };
            if let Err(e) = adopted {
                arena.release(p.slot);
                if let Some(da) = sp.arena.as_mut() {
                    da.release(p.slot);
                }
                if let Some(pk) = self.paged.as_mut() {
                    pk.release(p.slot);
                }
                self.out.respond(error_response(p.req.id, e));
                return;
            }
        }
        // graduate the warm-seeded prefix blocks to shared frames (the
        // chunked twin of `admit`'s post-adoption mark_shared)
        if let (Some(pk), Some(entry)) = (self.paged.as_mut(), p.warm_paged.as_ref()) {
            pk.mark_shared(p.slot, entry);
        }
        self.install_slot(
            p.slot,
            ActiveSlot {
                req: p.req,
                sampler,
                outputs,
                watch,
                next: first,
                effective_max,
                seq,
                _lease: p.lease,
            },
        );
    }
}

/// Return a chunked admission's reserved row(s) — and, in paged mode,
/// its attached blocks — to the free pool.
fn release_reservation(
    arena: &mut SlotArena,
    spec: Option<&mut SpecState>,
    paged: Option<&mut PagedKv>,
    slot: usize,
) {
    arena.release(slot);
    if let Some(sp) = spec {
        if let Some(da) = sp.arena.as_mut() {
            da.release(slot);
        }
    }
    if let Some(pk) = paged {
        pk.release(slot);
    }
}

/// Token at absolute context position `pos` of a resident request
/// (prompt, then committed outputs).
fn context_token(a: &ActiveSlot, pos: usize) -> u32 {
    let len = a.req.prompt.len();
    if pos < len {
        a.req.prompt[pos]
    } else {
        a.outputs[pos - len]
    }
}

impl<'a> IterationLoop<'a> {
    /// One scheduler iteration over the occupied rows. Plain mode commits
    /// exactly one token per row; speculative mode runs gamma batched draft
    /// steps + one width-W verify pass and commits 1..=W per row, rolling
    /// rejected suffixes back in both arenas. Exactness does not depend on
    /// draft quality: every committed token is the row's own sampler applied
    /// to target logits for the committed prefix, so greedy output is
    /// token-identical to plain serving (proposals only decide how far one
    /// iteration gets). Seeded stochastic sampling draws exactly one sample
    /// per committed token in order, but the width-W and width-1
    /// executables agree only to float tolerance, so a draw landing within
    /// ~1e-3 of a cumulative-probability edge can differ from plain mode.
    fn decode_iteration(&mut self) {
        let server = self.server;
        let iter = self.turns;
        let Some(arena) = self.arena.as_mut() else { return };
        let spec = self.spec.as_mut();
        let slots = &mut self.slots;
        let out = &mut self.out;
        let engine = &server.engine;
        // one small copy per iteration: the loop below mutates the arena
        // (set_pos/release) while walking the occupied set
        let occ: Vec<usize> = arena.occupied().to_vec();
        server.metrics.note_iteration_at(self.lane, occ.len(), arena.bucket_batch);

        // ---- width selection: speculate only when every occupied row has
        // context room for a full verify (and the draft for its proposals);
        // otherwise fall back to a plain width-1 iteration
        let mut draft_engine: Option<&Engine> = None;
        let mut draft_arena: Option<&mut SlotArena> = None;
        let mut width = 1usize;
        if let Some(sp) = spec {
            let w = sp.width;
            if let Some(da) = sp.arena.as_mut() {
                let fits = occ.iter().all(|&s| {
                    let (Some(tp), Some(dp)) = (arena.pos(s), da.pos(s)) else {
                        return false;
                    };
                    tp + w <= arena.max_ctx && dp + (w - 1) <= da.max_ctx
                });
                if fits {
                    width = w;
                }
                draft_engine = Some(&sp.engine);
                draft_arena = Some(da);
            }
        }
        let gamma = width - 1;
        let n = occ.len();

        // ---- draft phase: gamma batched steps over the draft arena. Each
        // step feeds, per row, the next committed-context token the draft
        // has not cached yet (catch-up after a rollback or a full-accept
        // bonus), or the draft's own last prediction once caught up — only
        // outputs past the committed context are proposals.
        let mut fed: Vec<Vec<u32>> = (0..n).map(|_| Vec::with_capacity(gamma)).collect();
        let mut proposals: Vec<Vec<u32>> = (0..n).map(|_| Vec::new()).collect();
        let mut dstart: Vec<usize> = vec![0; n];
        if gamma > 0 {
            let d0 = server.trace.begin();
            // nbl-lint: allow(panic): gamma > 0 only in the width-selection branch that saw the engine
            let dengine = draft_engine.expect("width > 1 implies a draft engine");
            // nbl-lint: allow(panic): gamma > 0 only in the width-selection branch that saw the arena
            let da = draft_arena.as_mut().expect("width > 1 implies a draft arena");
            for (i, &s) in occ.iter().enumerate() {
                // occupied target rows are lockstep-occupied in the draft
                // arena; 0 (unreachable) degrades to a full re-feed
                dstart[i] = da.pos(s).unwrap_or(0);
            }
            let mut last_out: Vec<u32> = vec![0; n];
            for _step in 0..gamma {
                let rows: Vec<RowDecode> = occ
                    .iter()
                    .enumerate()
                    .map(|(i, &s)| {
                        // nbl-lint: allow(panic): rows in `occ` hold an ActiveSlot (slots/arena lockstep)
                        let a = slots[s].as_ref().unwrap();
                        let d = da.pos(s).unwrap_or(0);
                        let l = a.req.prompt.len() + a.outputs.len();
                        let tok = if d < l { context_token(a, d) } else { last_out[i] };
                        fed[i].push(tok);
                        RowDecode { slot: s, token: tok }
                    })
                    .collect();
                let logits = match dengine.decode_rows(da, &rows) {
                    Ok(l) => l,
                    Err(e) => {
                        fail_iteration(
                            arena,
                            Some(&mut **da),
                            self.paged.as_mut(),
                            &occ,
                            slots,
                            out,
                            &e,
                            &server.trace,
                            iter,
                        );
                        return;
                    }
                };
                for (i, &s) in occ.iter().enumerate() {
                    last_out[i] = argmax(logits.at2(i, 0));
                    // nbl-lint: allow(panic): rows in `occ` hold an ActiveSlot (slots/arena lockstep)
                    let a = slots[s].as_ref().unwrap();
                    let l = a.req.prompt.len() + a.outputs.len();
                    // the token just cached sits at da.pos - 1; its successor
                    // prediction is a proposal once the context is consumed
                    if da.pos(s).unwrap_or(0) >= l {
                        proposals[i].push(last_out[i]);
                    }
                }
            }
            let proposed: u64 = proposals.iter().map(|p| p.len() as u64).sum();
            server.trace.span(SpanKind::SpecDraft, self.lane as u64, iter, d0, proposed);
        }

        // ---- verify phase: one width-W target pass over every row
        // `occ` rows are occupied by construction, so pos() is Some
        let tstart: Vec<usize> = occ.iter().map(|&s| arena.pos(s).unwrap_or(0)).collect();
        let vrows: Vec<RowSpecDecode> = occ
            .iter()
            .enumerate()
            .map(|(i, &s)| {
                // nbl-lint: allow(panic): rows in `occ` hold an ActiveSlot (slots/arena lockstep)
                let a = slots[s].as_ref().unwrap();
                let mut tokens = Vec::with_capacity(width);
                tokens.push(a.next);
                tokens.extend_from_slice(&proposals[i]);
                // rows short on proposals (draft was catching up) pad with
                // the last token; fillers only gate continuation, committed
                // tokens always come from the sampler over true logits
                while tokens.len() < width {
                    tokens.push(*tokens.last().unwrap_or(&a.next));
                }
                RowSpecDecode { slot: s, tokens }
            })
            .collect();
        let v0 = server.trace.begin();
        let vl = match engine.decode_rows_spec(arena, &vrows) {
            Ok(l) => l,
            Err(e) => {
                let da = draft_arena.as_mut().map(|x| &mut **x);
                fail_iteration(
                    arena,
                    da,
                    self.paged.as_mut(),
                    &occ,
                    slots,
                    out,
                    &e,
                    &server.trace,
                    iter,
                );
                return;
            }
        };
        if width > 1 {
            // the verify pass proper (plain width-1 iterations are
            // already the decode phase span)
            server.trace.span(SpanKind::SpecVerify, self.lane as u64, iter, v0, n as u64);
        }

        // ---- acceptance: commit the longest sampled prefix that agrees
        // with the verified tokens, then roll both arenas back to it
        let mut total_committed = 0usize;
        let mut total_proposed = 0usize;
        let mut total_accepted = 0usize;
        for (i, &s) in occ.iter().enumerate() {
            let (committed, done) = {
                // nbl-lint: allow(panic): rows in `occ` hold an ActiveSlot (slots/arena lockstep)
                let a = slots[s].as_mut().unwrap();
                let mut committed = 0usize;
                let mut done = false;
                for j in 0..width {
                    let tok = a.sampler.sample(vl.at2(i, j));
                    a.outputs.push(tok);
                    out.emit(a.req.id, a.outputs.len() - 1, tok);
                    a.next = tok;
                    committed += 1;
                    if Some(tok) == server.config.eos || a.outputs.len() >= a.effective_max {
                        done = true;
                        break;
                    }
                    if j + 1 < width && tok != vrows[i].tokens[j + 1] {
                        break; // divergence: the rest of the verify is stale
                    }
                }
                // one amortized mark for the whole commit: W back-to-back
                // marks would push near-zero intervals and poison the median
                // per-token throughput
                a.watch.mark_tokens(committed);
                (committed, done)
            };
            // rejected suffix: stale cache rows beyond the committed prefix
            // are masked by pos and overwritten by later writes
            arena.set_pos(s, tstart[i] + committed);
            total_committed += committed;
            total_proposed += proposals[i].len();
            total_accepted += (committed - 1).min(proposals[i].len());
            if let Some(da) = draft_arena.as_mut() {
                if gamma > 0 {
                    // re-anchor the draft on the committed context: keep the
                    // longest fed prefix that matches it (never past the last
                    // committed token, so the next round always re-feeds it)
                    // nbl-lint: allow(panic): rows in `occ` hold an ActiveSlot (slots/arena lockstep)
                    let a = slots[s].as_ref().unwrap();
                    let l_new = a.req.prompt.len() + a.outputs.len();
                    let mut valid = 0usize;
                    for (k, &t) in fed[i].iter().enumerate() {
                        let p = dstart[i] + k;
                        if p + 1 < l_new && t == context_token(a, p) {
                            valid += 1;
                        } else {
                            break;
                        }
                    }
                    da.set_pos(s, dstart[i] + valid);
                }
            }
            if done {
                // leave the batch: free the slot(s), paged blocks, and KV
                // lease without disturbing the other rows
                let Some(a) = slots[s].take() else { continue };
                arena.release(s);
                if let Some(da) = draft_arena.as_mut() {
                    da.release(s);
                }
                if let Some(pk) = self.paged.as_mut() {
                    pk.release(s);
                }
                let mut timing = a.watch.finish(a.req.prompt.len(), a.outputs.len());
                timing.deadline_met = deadline_met(a.req.deadline_ms, &timing);
                server
                    .trace
                    .instant(SpanKind::Finish, a.req.id, iter, a.outputs.len() as u64);
                let resp = ok_response(a.req.id, a.outputs, &timing);
                server.metrics.record(timing);
                out.respond(resp);
            }
        }
        server.metrics.note_committed_at(self.lane, total_committed);
        if width > 1 {
            server.metrics.note_spec_round_at(self.lane, total_proposed, total_accepted);
        }
    }
}

/// A failed iteration poisons the whole group: every resident request
/// gets an answer and its slot(s) — and, in paged mode, its blocks —
/// back.
#[allow(clippy::too_many_arguments)]
fn fail_iteration(
    arena: &mut SlotArena,
    draft: Option<&mut SlotArena>,
    paged: Option<&mut PagedKv>,
    occ: &[usize],
    slots: &mut [Option<ActiveSlot>],
    out: &mut Outbox,
    e: &Error,
    trace: &TraceRecorder,
    iter: u64,
) {
    for &s in occ {
        if let Some(a) = slots[s].take() {
            arena.release(s);
            trace.instant(SpanKind::ErrorEvt, a.req.id, iter, 0);
            out.respond(error_response(a.req.id, Error::msg(e.to_string())));
        }
    }
    if let Some(da) = draft {
        for &s in occ {
            da.release(s);
        }
    }
    if let Some(pk) = paged {
        for &s in occ {
            pk.release(s);
        }
    }
}

/// Legacy worker: exact-length groups served to completion. Stopwatches
/// start at SUBMISSION (not group formation), so TTFT includes queue
/// wait exactly like continuous mode — the two protocols are only
/// comparable on the same clock.
fn run_exact_length(server: &Arc<Server>, rx: &Receiver<Submission>) {
    let mut batcher = Batcher::new(server.config.max_batch);
    let mut replies: HashMap<u64, Sender<GenResponse>> = HashMap::new();
    let mut watches: HashMap<u64, Stopwatch> = HashMap::new();
    'outer: loop {
        // block for at least one submission, drain the rest
        let first = match rx.recv() {
            Ok(s) => s,
            Err(_) => break, // all senders dropped: shutdown
        };
        let mut pending = vec![first];
        while let Ok(s) = rx.try_recv() {
            pending.push(s);
        }
        let mut shutdown = false;
        for s in pending {
            match s {
                Submission::Shutdown => shutdown = true,
                // the legacy lockstep protocol runs groups to completion
                // and has no per-request teardown; cancellation is a
                // continuous-mode feature (the front end still answers
                // correctly — the request simply completes)
                Submission::Cancel(_) => {}
                Submission::Request(req, reply, watch, _sink) => {
                    replies.insert(req.id, reply);
                    watches.insert(req.id, watch);
                    batcher.push(req);
                }
            }
        }
        if shutdown {
            break 'outer;
        }
        while let Some(group) = batcher.next_group() {
            let group_watches: Vec<Stopwatch> =
                group.iter().map(|r| take_watch(&mut watches, r.id)).collect();
            let resp = server.run_group_timed(&group, group_watches).unwrap_or_else(|e| {
                group
                    .iter()
                    .map(|r| error_response(r.id, Error::msg(e.to_string())))
                    .collect()
            });
            for r in resp {
                respond(&mut replies, r);
            }
        }
    }
    // shutdown: requests drained alongside the shutdown submission (and
    // any leftover reply channels) still get an answer instead of a hang
    while let Some(group) = batcher.next_group() {
        for r in &group {
            respond(
                &mut replies,
                error_response(r.id, Error::Serving("server shut down".into())),
            );
        }
    }
    for (id, tx) in replies.drain() {
        let _ = tx.send(error_response(id, Error::Serving("server shut down".into())));
    }
}

/// Returns false on an explicit shutdown submission. Cancellations are
/// only buffered here: tearing one down needs the whole iteration
/// state (slots, arenas, the chunked machine), which the caller owns.
fn intake(
    sub: Submission,
    sched: &mut Scheduler,
    replies: &mut HashMap<u64, Sender<GenResponse>>,
    watches: &mut HashMap<u64, Stopwatch>,
    sinks: &mut HashMap<u64, Sender<StreamToken>>,
    cancels: &mut Vec<u64>,
    trace: &TraceRecorder,
) -> bool {
    match sub {
        Submission::Shutdown => false,
        Submission::Cancel(id) => {
            cancels.push(id);
            true
        }
        Submission::Request(req, reply, watch, sink) => {
            trace.instant(SpanKind::Submit, req.id, 0, req.prompt.len() as u64);
            replies.insert(req.id, reply);
            watches.insert(req.id, watch);
            if let Some(s) = sink {
                sinks.insert(req.id, s);
            }
            sched.push(req);
            true
        }
    }
}

/// Fetch the submission-time stopwatch for `id`. Every request gets one
/// at intake; a missing watch would silently restart the clock at
/// admission and erase queue wait from TTFT, so the invariant is loud:
/// debug builds assert, release builds log before falling back to a
/// fresh stopwatch (under-reporting beats killing the worker).
fn take_watch(watches: &mut HashMap<u64, Stopwatch>, id: u64) -> Stopwatch {
    match watches.remove(&id) {
        Some(mut w) => {
            // the single choke point every admission path passes through:
            // queue wait ends here (first call wins inside the watch)
            w.mark_admitted();
            w
        }
        None => {
            debug_assert!(false, "request {id} has no submission stopwatch");
            eprintln!(
                "server: request {id} missing its submission stopwatch; \
                 TTFT restarts at admission"
            );
            Stopwatch::new()
        }
    }
}

fn respond(replies: &mut HashMap<u64, Sender<GenResponse>>, resp: GenResponse) {
    if let Some(tx) = replies.remove(&resp.id) {
        let _ = tx.send(resp);
    }
}

/// Did a finished request meet its submission-relative deadline? None
/// when it never carried one: SLO attainment divides over deadlined
/// requests only, while goodput counts deadline-free requests
/// unconditionally (see `MetricsHub::record`).
fn deadline_met(deadline_ms: Option<u64>, t: &RequestTiming) -> Option<bool> {
    deadline_ms.map(|d| t.total_s * 1e3 <= d as f64)
}

pub(crate) enum Submission {
    // the stopwatch is started by the SUBMITTING thread, so TTFT always
    // includes channel + scheduler queue wait in every mode; the
    // optional sink receives each committed token as the scheduler
    // commits it (streaming front end)
    Request(GenRequest, Sender<GenResponse>, Stopwatch, Option<Sender<StreamToken>>),
    // abort a request wherever it currently lives; unknown ids are a
    // no-op (the cancel raced the final token)
    Cancel(u64),
    Shutdown,
}

pub struct ServerHandle {
    tx: Sender<Submission>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Assemble a handle around an already-spawned front thread (the
    /// replicated dispatcher); same submit/cancel/shutdown surface as a
    /// single-worker handle — callers cannot tell N replicas apart.
    pub(crate) fn from_parts(
        tx: Sender<Submission>,
        join: std::thread::JoinHandle<()>,
    ) -> ServerHandle {
        ServerHandle { tx, join: Some(join) }
    }

    /// Submit a request; returns a receiver for the response. The TTFT
    /// stopwatch starts here, on the submitting thread.
    pub fn submit(&self, req: GenRequest) -> Receiver<GenResponse> {
        let (tx, rx) = channel();
        let _ = self.tx.send(Submission::Request(req, tx, Stopwatch::new(), None));
        rx
    }

    /// Submit a streaming request: every committed token is forwarded
    /// on `sink` as the scheduler commits it (continuous mode; the
    /// legacy exact-length worker answers one-shot and the front end
    /// synthesizes the frames). The terminal response still arrives on
    /// the returned receiver, after the last sink token.
    pub fn submit_streaming(
        &self,
        req: GenRequest,
        sink: Sender<StreamToken>,
    ) -> Receiver<GenResponse> {
        let (tx, rx) = channel();
        let _ = self.tx.send(Submission::Request(req, tx, Stopwatch::new(), Some(sink)));
        rx
    }

    /// Cancel request `id`: wherever it lives — queued, chunk-
    /// prefilling, parked, or decoding — it is torn down through the
    /// normal release path (slot freed in both arenas, paged blocks
    /// returned) and answered with a typed [`Error::Cancelled`].
    /// Unknown ids are a no-op: the cancel raced the final token and
    /// the client already has its answer.
    pub fn cancel(&self, id: u64) {
        let _ = self.tx.send(Submission::Cancel(id));
    }

    pub fn submit_blocking(&self, req: GenRequest) -> Result<GenResponse> {
        self.submit(req)
            .recv()
            .map_err(|_| Error::Serving("server shut down".into()))
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Submission::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Submission::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn ok_response(id: u64, tokens: Vec<u32>, timing: &RequestTiming) -> GenResponse {
    GenResponse {
        id,
        text: ByteTokenizer::new().decode(&tokens),
        tokens,
        ttft_ms: timing.ttft_s * 1e3,
        total_ms: timing.total_s * 1e3,
        error: None,
    }
}

pub(crate) fn error_response(id: u64, e: Error) -> GenResponse {
    GenResponse {
        id,
        tokens: vec![],
        text: String::new(),
        ttft_ms: 0.0,
        total_ms: 0.0,
        error: Some(e.to_string()),
    }
}
