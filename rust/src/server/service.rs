//! The generation service: a worker thread running either the
//! continuous-batching scheduler (default) or the legacy lockstep group
//! protocol, plus a submit API used by both the TCP front-end and the
//! in-process benches.
//!
//! Continuous mode (DESIGN.md §Serving): the worker runs ONE decode
//! iteration at a time over the occupied rows of a per-request KV slot
//! arena. Finished requests leave the batch and free their slot
//! immediately; newly admitted requests (any prompt length) are
//! prefilled solo and join mid-flight. Admission is slot-granular
//! against the KV pool.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;

use crate::data::tokenizer::ByteTokenizer;
use crate::error::{Error, Result};
use crate::executor::engine::{Engine, RowDecode};
use crate::kvcache::{kv_bytes, slot_bytes, KvLeaseOwned, KvPool, SlotArena};
use crate::sampling::Sampler;
use crate::server::api::{GenRequest, GenResponse};
use crate::server::batcher::{Batcher, Scheduler};
use crate::server::metrics::{MetricsHub, RequestTiming, Stopwatch};

/// Worker-loop scheduling protocol.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchMode {
    /// Iteration-level continuous batching over per-request KV slots
    /// (the default).
    Continuous,
    /// Legacy lockstep protocol: exact-length groups run
    /// prefill->decode to completion (the benches' baseline).
    ExactLength,
}

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub max_batch: usize,
    /// KV pool capacity in bytes (admission control).
    pub kv_capacity_bytes: usize,
    /// Optional stop token.
    pub eos: Option<u32>,
    /// Scheduling protocol for the async worker.
    pub mode: BatchMode,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            kv_capacity_bytes: 1 << 30,
            eos: None,
            mode: BatchMode::Continuous,
        }
    }
}

pub struct Server {
    pub engine: Arc<Engine>,
    pub config: ServerConfig,
    pub metrics: Arc<MetricsHub>,
    pub pool: Arc<KvPool>,
}

impl Server {
    pub fn new(engine: Arc<Engine>, config: ServerConfig) -> Server {
        let pool = Arc::new(KvPool::new(config.kv_capacity_bytes));
        Server {
            engine,
            config,
            metrics: Arc::new(MetricsHub::new()),
            pool,
        }
    }

    /// Synchronously serve one request (the paper's batch-1 protocol).
    pub fn generate_one(&self, req: &GenRequest) -> GenResponse {
        match self.run_group(std::slice::from_ref(req)) {
            Ok(mut v) => v.pop().unwrap(),
            Err(e) => error_response(req.id, e),
        }
    }

    /// Serve a group of equal-length-prompt requests in lockstep — the
    /// legacy run-to-completion protocol, kept as the exact-length
    /// baseline the continuous scheduler is benchmarked against.
    pub fn run_group(&self, group: &[GenRequest]) -> Result<Vec<GenResponse>> {
        let n = group.len();
        if n == 0 {
            return Ok(vec![]);
        }
        let len = group[0].prompt.len();
        if group.iter().any(|r| r.prompt.len() != len) {
            return Err(Error::Serving("group prompts must share length".into()));
        }
        let cfg = self.engine.config();
        let bucket_b = self.engine.batch_bucket(n)?;
        let _lease = self.pool.reserve(kv_bytes(
            cfg,
            self.engine.plan.kv_layers(),
            bucket_b,
            cfg.max_ctx,
            4,
        ))?;

        let max_new: usize = group.iter().map(|r| r.max_new_tokens).max().unwrap_or(0);
        let budget = cfg.max_ctx.saturating_sub(len);
        let max_new = max_new.min(budget);

        let mut watches: Vec<Stopwatch> = group.iter().map(|_| Stopwatch::new()).collect();
        let mut samplers: Vec<Sampler> =
            group.iter().map(|r| Sampler::new(r.params.clone())).collect();
        let mut outputs: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut done: Vec<bool> = group.iter().map(|r| r.max_new_tokens == 0).collect();

        // prefill + first token
        let mut ids = Vec::with_capacity(n * len);
        for r in group {
            ids.extend_from_slice(&r.prompt);
        }
        let pre = self.engine.prefill(&ids, n, len, None)?;
        let mut state = pre.state;
        let logits = self.engine.head(&pre.hidden)?;
        let mut next: Vec<u32> = (0..n)
            .map(|b| samplers[b].sample(logits.at2(b, len - 1)))
            .collect();
        for b in 0..n {
            if !done[b] {
                watches[b].mark_token();
                outputs[b].push(next[b]);
                if Some(next[b]) == self.config.eos || outputs[b].len() >= group[b].max_new_tokens {
                    done[b] = true;
                }
            }
        }

        // lockstep decode
        for _step in 1..max_new {
            if done.iter().all(|&d| d) {
                break;
            }
            let logits = self.engine.decode(&mut state, &next, 1)?;
            for b in 0..n {
                if done[b] {
                    next[b] = 0; // keep feeding pad; output ignored
                    continue;
                }
                let tok = samplers[b].sample(logits.at2(b, 0));
                watches[b].mark_token();
                outputs[b].push(tok);
                next[b] = tok;
                if Some(tok) == self.config.eos || outputs[b].len() >= group[b].max_new_tokens {
                    done[b] = true;
                }
            }
        }

        // finalize
        let mut responses = Vec::with_capacity(n);
        for (b, (req, sw)) in group.iter().zip(watches.into_iter()).enumerate() {
            let timing = sw.finish(len, outputs[b].len());
            let resp = ok_response(req.id, std::mem::take(&mut outputs[b]), &timing);
            self.metrics.record(timing);
            responses.push(resp);
        }
        Ok(responses)
    }

    /// Spawn the worker loop; returns a handle for async submission.
    pub fn spawn(self: Arc<Self>) -> ServerHandle {
        let (tx, rx): (Sender<Submission>, Receiver<Submission>) = channel();
        let server = self.clone();
        let join = std::thread::spawn(move || match server.config.mode {
            BatchMode::Continuous => run_continuous(&server, &rx),
            BatchMode::ExactLength => run_exact_length(&server, &rx),
        });
        ServerHandle { tx, join: Some(join) }
    }
}

// ------------------------------------------------------------ worker loops

/// A request resident in the decode group: one occupied arena slot.
struct ActiveSlot {
    req: GenRequest,
    sampler: Sampler,
    outputs: Vec<u32>,
    watch: Stopwatch,
    /// Token to feed at the next decode iteration (sampled last
    /// iteration, or from the prefill logits at admission).
    next: u32,
    /// max_new_tokens clamped to the context budget.
    effective_max: usize,
    /// Slot-granular KV reservation; returns to the pool when the
    /// request leaves the batch.
    _lease: KvLeaseOwned,
}

/// Continuous-batching worker: one decode iteration per loop turn over
/// the occupied slots; admissions and departures happen between
/// iterations without restarting the batch.
fn run_continuous(server: &Arc<Server>, rx: &Receiver<Submission>) {
    let engine = &server.engine;
    let per_slot = slot_bytes(engine.config(), &engine.plan);
    let mut sched = Scheduler::new();
    let mut replies: HashMap<u64, Sender<GenResponse>> = HashMap::new();
    // stopwatches start at SUBMISSION so TTFT includes scheduler queue
    // wait (under load the queue is where latency lives)
    let mut watches: HashMap<u64, Stopwatch> = HashMap::new();
    let mut arena: Option<SlotArena> = None;
    let mut slots: Vec<Option<ActiveSlot>> = Vec::new();
    // rows that served an earlier request (slot-reuse accounting)
    let mut row_used: Vec<bool> = Vec::new();

    'outer: loop {
        // ---- intake: block when idle, poll between iterations
        let idle = slots.iter().all(|s| s.is_none()) && sched.waiting() == 0;
        if idle {
            match rx.recv() {
                Ok(sub) => {
                    if !intake(sub, &mut sched, &mut replies, &mut watches) {
                        break 'outer;
                    }
                }
                Err(_) => break 'outer, // all senders dropped
            }
        }
        loop {
            match rx.try_recv() {
                Ok(sub) => {
                    if !intake(sub, &mut sched, &mut replies, &mut watches) {
                        break 'outer;
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => break 'outer,
            }
        }

        // ---- lazily size the arena from the grid on first demand
        if arena.is_none() && sched.waiting() > 0 {
            match engine.new_arena(server.config.max_batch) {
                Ok(a) => {
                    slots = (0..a.bucket_batch).map(|_| None).collect();
                    row_used = vec![false; a.bucket_batch];
                    arena = Some(a);
                }
                Err(e) => {
                    for r in sched.drain() {
                        watches.remove(&r.id);
                        respond(&mut replies, error_response(r.id, Error::msg(e.to_string())));
                    }
                    continue;
                }
            }
        }
        let Some(arena_ref) = arena.as_mut() else { continue };

        // ---- admission: oldest-first into free slots while budget holds
        loop {
            let Some(slot) = arena_ref.free_slot() else { break };
            let free = arena_ref.bucket_batch - arena_ref.occupancy();
            let Some(req) = sched.next_admission(free, &server.pool, per_slot) else { break };
            let lease = match KvPool::reserve_owned(&server.pool, per_slot) {
                Ok(l) => l,
                Err(_) => {
                    // raced with an external reservation; retry next turn
                    sched.push_front(req);
                    break;
                }
            };
            let watch = watches.remove(&req.id).unwrap_or_default();
            admit(
                server, arena_ref, slot, req, watch, lease, &mut slots, &mut row_used,
                &mut replies,
            );
        }

        // ---- a head that can never fit must not hang the queue
        if arena_ref.occupancy() == 0
            && sched.waiting() > 0
            && !server.pool.would_fit(per_slot)
        {
            if server.pool.in_use() == 0 {
                let cap = server.pool.capacity();
                for r in sched.drain() {
                    watches.remove(&r.id);
                    respond(
                        &mut replies,
                        error_response(
                            r.id,
                            Error::Serving(format!(
                                "KV pool exhausted: slot needs {per_slot} > capacity {cap}"
                            )),
                        ),
                    );
                }
            } else {
                // an external lease holds the budget; yield briefly
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }

        // ---- one decode iteration over the occupied rows
        server
            .metrics
            .observe(sched.waiting(), server.pool.in_use(), server.pool.capacity());
        let occ = arena_ref.occupied();
        if occ.is_empty() {
            continue;
        }
        let rows: Vec<RowDecode> = occ
            .iter()
            .map(|&s| RowDecode { slot: s, token: slots[s].as_ref().unwrap().next })
            .collect();
        server.metrics.note_iteration(occ.len(), arena_ref.bucket_batch);
        match engine.decode_rows(arena_ref, &rows) {
            Err(e) => {
                // a failed iteration poisons the whole group: every
                // resident request gets an answer and its slot back
                for &s in &occ {
                    if let Some(a) = slots[s].take() {
                        arena_ref.release(s);
                        respond(&mut replies, error_response(a.req.id, Error::msg(e.to_string())));
                    }
                }
            }
            Ok(logits) => {
                for (i, &s) in occ.iter().enumerate() {
                    let done = {
                        let a = slots[s].as_mut().unwrap();
                        let tok = a.sampler.sample(logits.at2(i, 0));
                        a.watch.mark_token();
                        a.outputs.push(tok);
                        a.next = tok;
                        Some(tok) == server.config.eos || a.outputs.len() >= a.effective_max
                    };
                    if done {
                        // leave the batch: free the slot (and its KV
                        // lease) without disturbing the other rows
                        let a = slots[s].take().unwrap();
                        arena_ref.release(s);
                        let timing = a.watch.finish(a.req.prompt.len(), a.outputs.len());
                        let resp = ok_response(a.req.id, a.outputs, &timing);
                        server.metrics.record(timing);
                        respond(&mut replies, resp);
                    }
                }
            }
        }
    }

    // ---- shutdown: every queued and in-flight request gets an answer
    // (a silently dropped reply channel looks like a hung client)
    for r in sched.drain() {
        respond(&mut replies, error_response(r.id, Error::Serving("server shut down".into())));
    }
    for slot in slots.iter_mut() {
        if let Some(a) = slot.take() {
            let err = Error::Serving("server shut down".into());
            respond(&mut replies, error_response(a.req.id, err));
        }
    }
    for (id, tx) in replies.drain() {
        let _ = tx.send(error_response(id, Error::Serving("server shut down".into())));
    }
}

/// Prefill a newly admitted request solo, sample its first token, and
/// (unless it already finished) migrate its cache into arena row `slot`.
#[allow(clippy::too_many_arguments)]
fn admit(
    server: &Arc<Server>,
    arena: &mut SlotArena,
    slot: usize,
    req: GenRequest,
    mut watch: Stopwatch,
    lease: KvLeaseOwned,
    slots: &mut [Option<ActiveSlot>],
    row_used: &mut [bool],
    replies: &mut HashMap<u64, Sender<GenResponse>>,
) {
    let engine = &server.engine;
    let cfg = engine.config();
    let len = req.prompt.len();
    if req.max_new_tokens == 0 {
        let timing = watch.finish(len, 0);
        respond(replies, ok_response(req.id, Vec::new(), &timing));
        return;
    }
    let pre = match engine.prefill(&req.prompt, 1, len, None) {
        Ok(p) => p,
        Err(e) => {
            respond(replies, error_response(req.id, e));
            return;
        }
    };
    let logits = match engine.head(&pre.hidden) {
        Ok(l) => l,
        Err(e) => {
            respond(replies, error_response(req.id, e));
            return;
        }
    };
    let mut sampler = Sampler::new(req.params.clone());
    let first = sampler.sample(logits.at2(0, len - 1));
    watch.mark_token();
    let outputs = vec![first];
    let effective_max = req
        .max_new_tokens
        .min(cfg.max_ctx.saturating_sub(len))
        .max(1);
    if Some(first) == server.config.eos || outputs.len() >= effective_max {
        // finished on the prefill token: never occupies a slot
        let timing = watch.finish(len, outputs.len());
        let resp = ok_response(req.id, outputs, &timing);
        server.metrics.record(timing);
        respond(replies, resp);
        return;
    }
    if let Err(e) = arena.adopt(slot, &pre.state) {
        respond(replies, error_response(req.id, e));
        return;
    }
    server.metrics.note_admission(row_used[slot]);
    row_used[slot] = true;
    slots[slot] = Some(ActiveSlot {
        req,
        sampler,
        outputs,
        watch,
        next: first,
        effective_max,
        _lease: lease,
    });
}

/// Legacy worker: exact-length groups served to completion.
fn run_exact_length(server: &Arc<Server>, rx: &Receiver<Submission>) {
    let mut batcher = Batcher::new(server.config.max_batch);
    let mut replies: HashMap<u64, Sender<GenResponse>> = HashMap::new();
    'outer: loop {
        // block for at least one submission, drain the rest
        let first = match rx.recv() {
            Ok(s) => s,
            Err(_) => break, // all senders dropped: shutdown
        };
        let mut pending = vec![first];
        while let Ok(s) = rx.try_recv() {
            pending.push(s);
        }
        let mut shutdown = false;
        for s in pending {
            match s {
                Submission::Shutdown => shutdown = true,
                Submission::Request(req, reply) => {
                    replies.insert(req.id, reply);
                    batcher.push(req);
                }
            }
        }
        if shutdown {
            break 'outer;
        }
        while let Some(group) = batcher.next_group() {
            let resp = server.run_group(&group).unwrap_or_else(|e| {
                group
                    .iter()
                    .map(|r| error_response(r.id, Error::msg(e.to_string())))
                    .collect()
            });
            for r in resp {
                respond(&mut replies, r);
            }
        }
    }
    // shutdown: requests drained alongside the shutdown submission (and
    // any leftover reply channels) still get an answer instead of a hang
    while let Some(group) = batcher.next_group() {
        for r in &group {
            respond(
                &mut replies,
                error_response(r.id, Error::Serving("server shut down".into())),
            );
        }
    }
    for (id, tx) in replies.drain() {
        let _ = tx.send(error_response(id, Error::Serving("server shut down".into())));
    }
}

/// Returns false on an explicit shutdown submission.
fn intake(
    sub: Submission,
    sched: &mut Scheduler,
    replies: &mut HashMap<u64, Sender<GenResponse>>,
    watches: &mut HashMap<u64, Stopwatch>,
) -> bool {
    match sub {
        Submission::Shutdown => false,
        Submission::Request(req, reply) => {
            replies.insert(req.id, reply);
            watches.insert(req.id, Stopwatch::new());
            sched.push(req);
            true
        }
    }
}

fn respond(replies: &mut HashMap<u64, Sender<GenResponse>>, resp: GenResponse) {
    if let Some(tx) = replies.remove(&resp.id) {
        let _ = tx.send(resp);
    }
}

enum Submission {
    Request(GenRequest, Sender<GenResponse>),
    Shutdown,
}

pub struct ServerHandle {
    tx: Sender<Submission>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, req: GenRequest) -> Receiver<GenResponse> {
        let (tx, rx) = channel();
        let _ = self.tx.send(Submission::Request(req, tx));
        rx
    }

    pub fn submit_blocking(&self, req: GenRequest) -> Result<GenResponse> {
        self.submit(req)
            .recv()
            .map_err(|_| Error::Serving("server shut down".into()))
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Submission::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Submission::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn ok_response(id: u64, tokens: Vec<u32>, timing: &RequestTiming) -> GenResponse {
    GenResponse {
        id,
        text: ByteTokenizer::new().decode(&tokens),
        tokens,
        ttft_ms: timing.ttft_s * 1e3,
        total_ms: timing.total_s * 1e3,
        error: None,
    }
}

fn error_response(id: u64, e: Error) -> GenResponse {
    GenResponse {
        id,
        tokens: vec![],
        text: String::new(),
        ttft_ms: 0.0,
        total_ms: 0.0,
        error: Some(e.to_string()),
    }
}
