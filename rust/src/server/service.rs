//! The generation service: batched prefill + lockstep decode, a worker
//! thread pulling groups from the batcher, and a submit API used by both
//! the TCP front-end and the in-process benches.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::data::tokenizer::ByteTokenizer;
use crate::error::{Error, Result};
use crate::executor::engine::Engine;
use crate::kvcache::{kv_bytes, KvPool};
use crate::sampling::Sampler;
use crate::server::api::{GenRequest, GenResponse};
use crate::server::batcher::Batcher;
use crate::server::metrics::{MetricsHub, Stopwatch};

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub max_batch: usize,
    /// KV pool capacity in bytes (admission control).
    pub kv_capacity_bytes: usize,
    /// Optional stop token.
    pub eos: Option<u32>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            kv_capacity_bytes: 1 << 30,
            eos: None,
        }
    }
}

pub struct Server {
    pub engine: Arc<Engine>,
    pub config: ServerConfig,
    pub metrics: Arc<MetricsHub>,
    pub pool: Arc<KvPool>,
}

impl Server {
    pub fn new(engine: Arc<Engine>, config: ServerConfig) -> Server {
        let pool = Arc::new(KvPool::new(config.kv_capacity_bytes));
        Server {
            engine,
            config,
            metrics: Arc::new(MetricsHub::new()),
            pool,
        }
    }

    /// Synchronously serve one request (the paper's batch-1 protocol).
    pub fn generate_one(&self, req: &GenRequest) -> GenResponse {
        match self.run_group(std::slice::from_ref(req)) {
            Ok(mut v) => v.pop().unwrap(),
            Err(e) => error_response(req.id, e),
        }
    }

    /// Serve a group of equal-length-prompt requests in lockstep.
    pub fn run_group(&self, group: &[GenRequest]) -> Result<Vec<GenResponse>> {
        let n = group.len();
        if n == 0 {
            return Ok(vec![]);
        }
        let len = group[0].prompt.len();
        if group.iter().any(|r| r.prompt.len() != len) {
            return Err(Error::Serving("group prompts must share length".into()));
        }
        let cfg = self.engine.config();
        let bucket_b = self.engine.batch_bucket(n)?;
        let _lease = self.pool.reserve(kv_bytes(
            cfg,
            self.engine.plan.kv_layers(),
            bucket_b,
            cfg.max_ctx,
            4,
        ))?;

        let max_new: usize = group.iter().map(|r| r.max_new_tokens).max().unwrap_or(0);
        let budget = cfg.max_ctx.saturating_sub(len);
        let max_new = max_new.min(budget);

        let mut watches: Vec<Stopwatch> = group.iter().map(|_| Stopwatch::new()).collect();
        let mut samplers: Vec<Sampler> =
            group.iter().map(|r| Sampler::new(r.params.clone())).collect();
        let mut outputs: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut done: Vec<bool> = group.iter().map(|r| r.max_new_tokens == 0).collect();

        // prefill + first token
        let mut ids = Vec::with_capacity(n * len);
        for r in group {
            ids.extend_from_slice(&r.prompt);
        }
        let pre = self.engine.prefill(&ids, n, len, None)?;
        let mut state = pre.state;
        let logits = self.engine.head(&pre.hidden)?;
        let mut next: Vec<u32> = (0..n)
            .map(|b| samplers[b].sample(logits.at2(b, len - 1)))
            .collect();
        for b in 0..n {
            if !done[b] {
                watches[b].mark_token();
                outputs[b].push(next[b]);
                if Some(next[b]) == self.config.eos || outputs[b].len() >= group[b].max_new_tokens {
                    done[b] = true;
                }
            }
        }

        // lockstep decode
        for _step in 1..max_new {
            if done.iter().all(|&d| d) {
                break;
            }
            let logits = self.engine.decode(&mut state, &next, 1)?;
            for b in 0..n {
                if done[b] {
                    next[b] = 0; // keep feeding pad; output ignored
                    continue;
                }
                let tok = samplers[b].sample(logits.at2(b, 0));
                watches[b].mark_token();
                outputs[b].push(tok);
                next[b] = tok;
                if Some(tok) == self.config.eos || outputs[b].len() >= group[b].max_new_tokens {
                    done[b] = true;
                }
            }
        }

        // finalize
        let tok = ByteTokenizer::new();
        let mut responses = Vec::with_capacity(n);
        for (b, (req, sw)) in group.iter().zip(watches.into_iter()).enumerate() {
            let timing = sw.finish(len, outputs[b].len());
            let resp = GenResponse {
                id: req.id,
                text: tok.decode(&outputs[b]),
                tokens: std::mem::take(&mut outputs[b]),
                ttft_ms: timing.ttft_s * 1e3,
                total_ms: timing.total_s * 1e3,
                error: None,
            };
            self.metrics.record(timing);
            responses.push(resp);
        }
        Ok(responses)
    }

    /// Spawn the worker loop; returns a handle for async submission.
    pub fn spawn(self: Arc<Self>) -> ServerHandle {
        let (tx, rx): (Sender<Submission>, Receiver<Submission>) = channel();
        let server = self.clone();
        let join = std::thread::spawn(move || {
            let mut batcher = Batcher::new(server.config.max_batch);
            let mut replies: std::collections::HashMap<u64, Sender<GenResponse>> =
                std::collections::HashMap::new();
            loop {
                // block for at least one submission, drain the rest
                let first = match rx.recv() {
                    Ok(s) => s,
                    Err(_) => break, // all senders dropped: shutdown
                };
                match first {
                    Submission::Shutdown => break,
                    Submission::Request(req, reply) => {
                        replies.insert(req.id, reply);
                        batcher.push(req);
                    }
                }
                while let Ok(s) = rx.try_recv() {
                    match s {
                        Submission::Shutdown => return,
                        Submission::Request(req, reply) => {
                            replies.insert(req.id, reply);
                            batcher.push(req);
                        }
                    }
                }
                while let Some(group) = batcher.next_group() {
                    let resp = server
                        .run_group(&group)
                        .unwrap_or_else(|e| {
                            group
                                .iter()
                                .map(|r| error_response(r.id, Error::msg(e.to_string())))
                                .collect()
                        });
                    for r in resp {
                        if let Some(tx) = replies.remove(&r.id) {
                            let _ = tx.send(r);
                        }
                    }
                }
            }
        });
        ServerHandle { tx, join: Some(join) }
    }
}

enum Submission {
    Request(GenRequest, Sender<GenResponse>),
    Shutdown,
}

pub struct ServerHandle {
    tx: Sender<Submission>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, req: GenRequest) -> Receiver<GenResponse> {
        let (tx, rx) = channel();
        let _ = self.tx.send(Submission::Request(req, tx));
        rx
    }

    pub fn submit_blocking(&self, req: GenRequest) -> Result<GenResponse> {
        self.submit(req)
            .recv()
            .map_err(|_| Error::Serving("server shut down".into()))
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Submission::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Submission::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn error_response(id: u64, e: Error) -> GenResponse {
    GenResponse {
        id,
        tokens: vec![],
        text: String::new(),
        ttft_ms: 0.0,
        total_ms: 0.0,
        error: Some(e.to_string()),
    }
}
