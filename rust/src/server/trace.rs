//! Flight recorder: a bounded ring-buffer journal of typed span events.
//!
//! Every request's life — submit → admit {cold, warm-prefix, chunked} →
//! prefill chunks → decode iterations → spec draft/verify rounds →
//! preempt/park → resume → finish/error — is recorded as fixed-size
//! `SpanRecord`s in a preallocated ring (DESIGN.md §Observability).
//! Recording is opt-in via `ServerConfig.trace_events` (0 = off); when
//! disabled every hook is a branch on a plain field — no `Instant::now`,
//! no lock, no allocation on the hot path.
//!
//! Two design choices keep the export trivially valid Chrome-trace JSON:
//!
//! 1. The ring stores *complete* spans (start + duration), pushed when
//!    the span ends. B/E event pairs are generated at export time from
//!    one record, so begin/end balance holds by construction even after
//!    the ring overwrites arbitrary records: span intervals per lane
//!    form a laminar family, and any subset of a laminar family is
//!    still properly nested.
//! 2. Export sorts events by `(ts, class, duration)` with E before B at
//!    equal timestamps, longer spans opening first and shorter spans
//!    closing first — so microsecond-tie nesting (a decode span and its
//!    first spec-draft span starting in the same µs) still yields a
//!    stack-valid event stream.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::lock_unpoisoned;

/// Typed event vocabulary. Spans carry a duration; instants are
/// zero-width markers. Request-lane events render under `pid = 1,
/// tid = request id`; worker-lane events (the iteration loop's phases)
/// under `pid = 0, tid = replica lane` — the `req` field of a
/// worker-lane record carries the replica's lane id, so an N-replica
/// server exports N distinct worker lanes that never collide with
/// request ids (DESIGN.md §Data parallelism).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    // request lane: spans
    /// submit → admission (the time a request waited in the FIFO)
    Queue,
    /// whole-prompt prefill admission, no cache hit
    AdmitCold,
    /// admission that adopted a cached prefix and prefilled the suffix
    AdmitWarm,
    /// multi-iteration chunked admission, start → final chunk
    AdmitChunked,
    /// one prefill chunk inside a chunked admission
    PrefillChunk,
    /// preempt → resume (KV pages reclaimed, request parked host-side)
    Park,
    // worker lane: per-iteration phases
    Intake,
    Admission,
    AdvanceChunked,
    Observe,
    Decode,
    /// gamma draft steps inside a decode iteration (spec mode)
    SpecDraft,
    /// widened verify pass inside a decode iteration (spec mode)
    SpecVerify,
    // instants
    Submit,
    Preempt,
    Resume,
    Finish,
    ErrorEvt,
    /// client cancelled the request (explicit frame or disconnect)
    Cancel,
    /// per-request deadline exceeded mid-flight (active/pending/parked)
    Expire,
    /// deadline already blown while still queued — dropped pre-admission
    Shed,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Queue => "queue",
            SpanKind::AdmitCold => "admit_cold",
            SpanKind::AdmitWarm => "admit_warm",
            SpanKind::AdmitChunked => "admit_chunked",
            SpanKind::PrefillChunk => "prefill_chunk",
            SpanKind::Park => "park",
            SpanKind::Intake => "intake",
            SpanKind::Admission => "admission",
            SpanKind::AdvanceChunked => "advance_chunked",
            SpanKind::Observe => "observe",
            SpanKind::Decode => "decode",
            SpanKind::SpecDraft => "spec_draft",
            SpanKind::SpecVerify => "spec_verify",
            SpanKind::Submit => "submit",
            SpanKind::Preempt => "preempt",
            SpanKind::Resume => "resume",
            SpanKind::Finish => "finish",
            SpanKind::ErrorEvt => "error",
            SpanKind::Cancel => "cancel",
            SpanKind::Expire => "expire",
            SpanKind::Shed => "shed",
        }
    }

    pub fn is_instant(self) -> bool {
        matches!(
            self,
            SpanKind::Submit
                | SpanKind::Preempt
                | SpanKind::Resume
                | SpanKind::Finish
                | SpanKind::ErrorEvt
                | SpanKind::Cancel
                | SpanKind::Expire
                | SpanKind::Shed
        )
    }

    /// Worker-lane events describe the iteration loop itself and render
    /// under pid 0 with `tid = replica lane` (carried in `req`);
    /// everything else renders on the request's own lane under pid 1.
    fn worker_lane(self) -> bool {
        matches!(
            self,
            SpanKind::Intake
                | SpanKind::Admission
                | SpanKind::AdvanceChunked
                | SpanKind::Observe
                | SpanKind::Decode
                | SpanKind::SpecDraft
                | SpanKind::SpecVerify
        )
    }
}

/// One complete event: fixed-size, `Copy`, no heap — the ring is a
/// preallocated `Vec<SpanRecord>` that never reallocates.
#[derive(Clone, Copy, Debug)]
pub struct SpanRecord {
    pub kind: SpanKind,
    /// request id — except for worker-lane kinds, where this field
    /// carries the replica lane id instead (0 for a single worker)
    pub req: u64,
    /// iteration-loop turn counter at record time
    pub iter: u64,
    /// start, microseconds since the recorder's epoch
    pub t0_us: u64,
    /// width (0 for instants)
    pub dur_us: u64,
    /// kind-specific payload: tokens for prefill/decode spans, accepted
    /// count for spec verify, parked bytes for preempt — see DESIGN.md
    pub arg: u64,
}

/// Counters surfaced on the stats endpoint (`trace_*` keys).
#[derive(Clone, Copy, Debug, Default)]
pub struct TraceStats {
    pub capacity: usize,
    pub recorded: u64,
    pub dropped: u64,
}

struct Ring {
    events: Vec<SpanRecord>,
    /// next overwrite position once `events` is full
    head: usize,
    recorded: u64,
    dropped: u64,
}

/// The recorder itself. `capacity == 0` disables every hook before it
/// reads the clock or touches the lock.
pub struct TraceRecorder {
    capacity: usize,
    epoch: Instant,
    ring: Mutex<Ring>,
}

impl TraceRecorder {
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity,
            epoch: Instant::now(),
            ring: Mutex::new(Ring {
                events: Vec::with_capacity(capacity),
                head: 0,
                recorded: 0,
                dropped: 0,
            }),
        }
    }

    /// Disabled recorder (`trace_events = 0`): all hooks early-return.
    pub fn disabled() -> Self {
        Self::new(0)
    }

    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Timestamp a span start. Returns 0 without reading the clock when
    /// tracing is off — the matching `span()` call discards it.
    #[inline]
    pub fn begin(&self) -> u64 {
        if self.capacity == 0 {
            return 0;
        }
        self.now_us()
    }

    /// Record a span opened by `begin()`, ending now.
    #[inline]
    pub fn span(&self, kind: SpanKind, req: u64, iter: u64, t0_us: u64, arg: u64) {
        if self.capacity == 0 {
            return;
        }
        let end = self.now_us();
        self.push(SpanRecord {
            kind,
            req,
            iter,
            t0_us,
            dur_us: end.saturating_sub(t0_us),
            arg,
        });
    }

    /// Record a span that ends now and started `dur_s` seconds ago —
    /// for intervals whose start predates the hook (queue wait measured
    /// by the request's stopwatch, park time measured at resume).
    #[inline]
    pub fn span_backdated(&self, kind: SpanKind, req: u64, iter: u64, dur_s: f64, arg: u64) {
        if self.capacity == 0 {
            return;
        }
        let end = self.now_us();
        // clamp to the recorder's epoch so t0 + dur == end stays exact
        let dur_us = (dur_s.max(0.0) * 1e6) as u64;
        let t0_us = end.saturating_sub(dur_us);
        self.push(SpanRecord { kind, req, iter, t0_us, dur_us: end - t0_us, arg });
    }

    /// Record a zero-width marker.
    #[inline]
    pub fn instant(&self, kind: SpanKind, req: u64, iter: u64, arg: u64) {
        if self.capacity == 0 {
            return;
        }
        let t0_us = self.now_us();
        self.push(SpanRecord { kind, req, iter, t0_us, dur_us: 0, arg });
    }

    fn push(&self, rec: SpanRecord) {
        let mut ring = lock_unpoisoned(&self.ring);
        if ring.events.len() < self.capacity {
            ring.events.push(rec);
        } else {
            // overwrite-oldest: the flight recorder keeps the most
            // recent window, which is the one you want after an incident
            let head = ring.head;
            ring.events[head] = rec;
            ring.head = (head + 1) % self.capacity;
            ring.dropped += 1;
        }
        ring.recorded += 1;
    }

    pub fn stats(&self) -> TraceStats {
        let ring = lock_unpoisoned(&self.ring);
        TraceStats {
            capacity: self.capacity,
            recorded: ring.recorded,
            dropped: ring.dropped,
        }
    }

    /// Snapshot the ring in record order (oldest first).
    fn snapshot(&self) -> Vec<SpanRecord> {
        let ring = lock_unpoisoned(&self.ring);
        let mut out = Vec::with_capacity(ring.events.len());
        out.extend_from_slice(&ring.events[ring.head..]);
        out.extend_from_slice(&ring.events[..ring.head]);
        out
    }

    /// Export as Chrome-trace JSON (`chrome://tracing`, Perfetto).
    ///
    /// The ring is snapshotted under the lock and released before any
    /// JSON is built — serialization cost never blocks recording
    /// (no-guard-across-blocking, nbl-lint pass `guard`).
    pub fn export_chrome(&self) -> Json {
        let records = self.snapshot();

        // (ts, class, tiebreak, idx_key, event). class orders same-µs
        // events into a stack-valid stream: ends close before new begins
        // open (E=0 < B=1), instants float after (2). Among same-ts B's
        // the longer span opens first; among same-ts E's the shorter
        // closes first. When even durations tie (two spans sharing both
        // endpoints at µs resolution), the ring index breaks it: spans
        // are pushed at END time by one worker thread, so on any lane
        // the inner span lands in the ring before its enclosing one —
        // E's replay in push order (inner closes first), B's in reverse
        // (outer opens first). Zero-width spans render 1µs wide so their
        // B still precedes their E.
        let mut events: Vec<(u64, u8, u64, u64, Json)> = Vec::with_capacity(records.len() * 2);
        for (idx, r) in records.iter().enumerate() {
            // worker-lane records carry the replica lane id in `req`
            // and render under their own pid so replica lane ids can
            // never collide with request ids on the request pid
            let worker = r.kind.worker_lane();
            let (pid, tid) = if worker { (0u64, r.req) } else { (1u64, r.req) };
            let cat = if worker { "worker" } else { "request" };
            let args = Json::obj(vec![
                ("req", Json::Num(r.req as f64)),
                ("iter", Json::Num(r.iter as f64)),
                ("arg", Json::Num(r.arg as f64)),
            ]);
            let base = |ph: &str, ts: u64| {
                Json::obj(vec![
                    ("name", Json::Str(r.kind.name().into())),
                    ("cat", Json::Str(cat.into())),
                    ("ph", Json::Str(ph.into())),
                    ("ts", Json::Num(ts as f64)),
                    ("pid", Json::Num(pid as f64)),
                    ("tid", Json::Num(tid as f64)),
                    ("args", args.clone()),
                ])
            };
            if r.kind.is_instant() {
                let mut j = base("i", r.t0_us);
                j.set("s", Json::Str("t".into()));
                events.push((r.t0_us, 2, 0, 0, j));
            } else {
                let dur = r.dur_us.max(1);
                let end = r.t0_us + dur;
                let b_idx = u64::MAX - idx as u64;
                events.push((r.t0_us, 1, u64::MAX - dur, b_idx, base("B", r.t0_us)));
                events.push((end, 0, dur, idx as u64, base("E", end)));
            }
        }
        events.sort_by(|a, b| (a.0, a.1, a.2, a.3).cmp(&(b.0, b.1, b.2, b.3)));

        Json::obj(vec![
            ("traceEvents", Json::Arr(events.into_iter().map(|e| e.4).collect())),
            ("displayTimeUnit", Json::Str("ms".into())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn names(j: &Json, ph: &str) -> Vec<String> {
        j.get("traceEvents")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == ph)
            .map(|e| e.get("name").unwrap().as_str().unwrap().to_string())
            .collect()
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let t = TraceRecorder::disabled();
        assert!(!t.enabled());
        assert_eq!(t.begin(), 0);
        t.span(SpanKind::Decode, 1, 1, 0, 4);
        t.instant(SpanKind::Submit, 1, 0, 0);
        t.span_backdated(SpanKind::Queue, 1, 0, 0.5, 0);
        let s = t.stats();
        assert_eq!((s.capacity, s.recorded, s.dropped), (0, 0, 0));
        let j = t.export_chrome();
        assert_eq!(j.get("traceEvents").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn spans_export_balanced_and_sorted() {
        let t = TraceRecorder::new(64);
        t.instant(SpanKind::Submit, 7, 0, 0);
        let t0 = t.begin();
        t.span(SpanKind::AdmitCold, 7, 1, t0, 16);
        let t1 = t.begin();
        t.span(SpanKind::Decode, 7, 2, t1, 1);
        t.instant(SpanKind::Finish, 7, 3, 0);
        let j = t.export_chrome();
        let b = names(&j, "B");
        let e = names(&j, "E");
        assert_eq!(b.len(), 2);
        assert_eq!(b, e, "every B has a matching E in order");
        assert_eq!(names(&j, "i"), vec!["submit", "finish"]);
        // timestamps globally non-decreasing
        let ts: Vec<f64> = j
            .get("traceEvents")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|ev| ev.get("ts").unwrap().as_f64().unwrap())
            .collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]), "unsorted ts: {ts:?}");
    }

    #[test]
    fn same_microsecond_nesting_stays_stack_valid() {
        // an outer decode span and an inner spec_draft span that share
        // start and end microseconds: the tie-break must open the outer
        // first and close the inner first
        let t = TraceRecorder::new(16);
        t.push(SpanRecord {
            kind: SpanKind::SpecDraft,
            req: 0,
            iter: 1,
            t0_us: 100,
            dur_us: 50,
            arg: 0,
        });
        t.push(SpanRecord {
            kind: SpanKind::Decode,
            req: 0,
            iter: 1,
            t0_us: 100,
            dur_us: 150,
            arg: 0,
        });
        let j = t.export_chrome();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap().to_vec();
        let seq: Vec<(String, String)> = evs
            .iter()
            .map(|ev| {
                (
                    ev.get("ph").unwrap().as_str().unwrap().to_string(),
                    ev.get("name").unwrap().as_str().unwrap().to_string(),
                )
            })
            .collect();
        // replay the stream against a stack: B pushes, E must match top
        let mut stack = Vec::new();
        for (ph, name) in &seq {
            match ph.as_str() {
                "B" => stack.push(name.clone()),
                "E" => assert_eq!(stack.pop().as_ref(), Some(name), "stream {seq:?}"),
                _ => {}
            }
        }
        assert!(stack.is_empty());
        assert_eq!(seq[0], ("B".into(), "decode".into()), "outer opens first");
    }

    #[test]
    fn zero_width_and_identical_interval_spans_stay_stack_valid() {
        // sub-µs spans collapse to dur 0 at record time, and an inner
        // span can share BOTH endpoints with its enclosing span; the
        // exporter's 1µs floor + ring-index tie-break must keep the
        // stream a valid LIFO per lane in both cases
        let t = TraceRecorder::new(16);
        // zero-width queue span (admission on the same µs as submit)
        t.push(SpanRecord {
            kind: SpanKind::Queue,
            req: 5,
            iter: 0,
            t0_us: 100,
            dur_us: 0,
            arg: 0,
        });
        // identical-interval pair: inner prefill_chunk pushed first
        // (spans land in the ring at END time, inner ends first)
        t.push(SpanRecord {
            kind: SpanKind::PrefillChunk,
            req: 5,
            iter: 1,
            t0_us: 200,
            dur_us: 40,
            arg: 8,
        });
        t.push(SpanRecord {
            kind: SpanKind::AdmitChunked,
            req: 5,
            iter: 1,
            t0_us: 200,
            dur_us: 40,
            arg: 8,
        });
        let j = t.export_chrome();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap().to_vec();
        let mut stack: Vec<String> = Vec::new();
        let mut last_ts = 0.0f64;
        for ev in &evs {
            let ts = ev.get("ts").unwrap().as_f64().unwrap();
            assert!(ts >= last_ts, "ts must stay non-decreasing");
            last_ts = ts;
            let name = ev.get("name").unwrap().as_str().unwrap().to_string();
            match ev.get("ph").unwrap().as_str().unwrap() {
                "B" => stack.push(name),
                "E" => assert_eq!(stack.pop(), Some(name), "LIFO violated"),
                _ => {}
            }
        }
        assert!(stack.is_empty(), "unclosed spans: {stack:?}");
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let t = TraceRecorder::new(4);
        for i in 0..10u64 {
            t.push(SpanRecord {
                kind: SpanKind::Decode,
                req: i,
                iter: i,
                t0_us: i * 100,
                dur_us: 10,
                arg: 0,
            });
        }
        let s = t.stats();
        assert_eq!((s.capacity, s.recorded, s.dropped), (4, 10, 6));
        let kept = t.snapshot();
        let reqs: Vec<u64> = kept.iter().map(|r| r.req).collect();
        assert_eq!(reqs, vec![6, 7, 8, 9], "most recent window survives, in order");
        // a post-overwrite export is still balanced
        let j = t.export_chrome();
        assert_eq!(names(&j, "B"), names(&j, "E"));
    }

    #[test]
    fn cancel_expire_shed_are_request_lane_instants() {
        for (kind, name) in [
            (SpanKind::Cancel, "cancel"),
            (SpanKind::Expire, "expire"),
            (SpanKind::Shed, "shed"),
        ] {
            assert!(kind.is_instant(), "{name} must be zero-width");
            assert!(!kind.worker_lane(), "{name} renders on the request lane");
            assert_eq!(kind.name(), name);
        }
        let t = TraceRecorder::new(8);
        t.instant(SpanKind::Cancel, 9, 2, 0);
        t.instant(SpanKind::Expire, 10, 2, 0);
        t.instant(SpanKind::Shed, 11, 2, 0);
        let j = t.export_chrome();
        assert_eq!(names(&j, "i"), vec!["cancel", "expire", "shed"]);
    }

    #[test]
    fn worker_lanes_export_per_replica_tids() {
        // two replicas interleave iteration phases; each replica's
        // worker spans must land on its own (pid=0, tid=lane) lane and
        // stay LIFO-balanced there, while request events keep pid=1
        let t = TraceRecorder::new(32);
        for lane in 0..2u64 {
            let t0 = t.begin();
            t.span(SpanKind::Intake, lane, 1, t0, 0);
            let t1 = t.begin();
            t.span(SpanKind::Decode, lane, 1, t1, 4);
        }
        t.instant(SpanKind::Submit, 41, 0, 0);
        let j = t.export_chrome();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap().to_vec();
        let mut worker_tids = std::collections::BTreeSet::new();
        for ev in &evs {
            let pid = ev.get("pid").unwrap().as_f64().unwrap() as u64;
            let tid = ev.get("tid").unwrap().as_f64().unwrap() as u64;
            let cat = ev.get("cat").unwrap().as_str().unwrap();
            if cat == "worker" {
                assert_eq!(pid, 0, "worker lanes render under pid 0");
                worker_tids.insert(tid);
            } else {
                assert_eq!(pid, 1, "request lanes render under pid 1");
                assert_eq!(tid, 41);
            }
        }
        assert_eq!(worker_tids.into_iter().collect::<Vec<_>>(), vec![0, 1]);
        // per-(pid,tid) LIFO balance, as ci/check_trace.py enforces
        let mut stacks: std::collections::HashMap<(u64, u64), Vec<String>> =
            std::collections::HashMap::new();
        for ev in &evs {
            let key = (
                ev.get("pid").unwrap().as_f64().unwrap() as u64,
                ev.get("tid").unwrap().as_f64().unwrap() as u64,
            );
            let name = ev.get("name").unwrap().as_str().unwrap().to_string();
            match ev.get("ph").unwrap().as_str().unwrap() {
                "B" => stacks.entry(key).or_default().push(name),
                "E" => assert_eq!(stacks.entry(key).or_default().pop(), Some(name)),
                _ => {}
            }
        }
        assert!(stacks.values().all(|s| s.is_empty()));
    }

    #[test]
    fn backdated_span_lands_before_its_end() {
        let t = TraceRecorder::new(8);
        std::thread::sleep(std::time::Duration::from_millis(15));
        t.span_backdated(SpanKind::Queue, 3, 0, 0.010, 0);
        let end = t.now_us();
        let rec = t.snapshot()[0];
        assert_eq!(rec.kind, SpanKind::Queue);
        assert_eq!(rec.dur_us, 10_000);
        assert!(rec.t0_us + rec.dur_us <= end, "span ends at record time");
        // a backdated span longer than the recorder's life clamps to
        // the epoch instead of underflowing
        t.span_backdated(SpanKind::Park, 3, 0, 1e6, 0);
        let rec = t.snapshot()[1];
        assert_eq!(rec.t0_us, 0);
        assert!(rec.t0_us + rec.dur_us <= t.now_us());
    }
}
