//! nbl-lint — repo-specific invariant lints for the serving stack.
//!
//! Run from the repo root (see DESIGN.md §Static analysis):
//!
//!   cargo run --manifest-path rust/nbl-lint/Cargo.toml -- --root .
//!   cargo run --manifest-path rust/nbl-lint/Cargo.toml -- --root . --dump-gauges
//!
//! Passes:
//!   panic   hot-path panic audit over server/ executor/ kvcache/
//!   charge  KvPool charge/refund pairing (try_take vs give_back/lease)
//!   guard   no Mutex/RwLock guard live across blocking calls
//!   gauge   MetricsHub <-> stats endpoint <-> bench emitter coherence
//!   unsafe  unsafe_code allowlist over all of rust/src
//!
//! Exit status: 0 clean, 1 findings, 2 usage/IO error.

mod gauges;
mod lexer;
mod passes;

use lexer::ScannedFile;
use passes::Finding;
use std::path::{Path, PathBuf};

/// Hot-path scope for the panic/charge/guard passes.
const HOT_DIRS: &[&str] = &["rust/src/server", "rust/src/executor", "rust/src/kvcache"];
/// unsafe_code allowlist scope.
const UNSAFE_DIR: &str = "rust/src";

fn rs_files(dir: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir) else {
        return out;
    };
    let mut entries: Vec<_> = entries.flatten().map(|e| e.path()).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            out.extend(rs_files(&p));
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    out
}

fn scan(root: &Path, path: &Path) -> Option<ScannedFile> {
    let src = std::fs::read_to_string(path).ok()?;
    let rel = path.strip_prefix(root).unwrap_or(path).display().to_string();
    Some(ScannedFile::scan(&rel, &src))
}

pub fn run_all(root: &Path) -> Vec<Finding> {
    let mut out = Vec::new();
    for d in HOT_DIRS {
        for p in rs_files(&root.join(d)) {
            let Some(f) = scan(root, &p) else { continue };
            passes::panic_pass(&f, &mut out);
            passes::charge_pass(&f, &mut out);
            passes::guard_pass(&f, &mut out);
        }
    }
    for p in rs_files(&root.join(UNSAFE_DIR)) {
        let Some(f) = scan(root, &p) else { continue };
        passes::unsafe_pass(&f, &mut out);
    }
    gauges::gauge_pass(root, &mut out);
    out.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    out
}

fn main() {
    let mut root = PathBuf::from(".");
    let mut dump_gauges = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(r) => root = PathBuf::from(r),
                None => {
                    eprintln!("nbl-lint: --root needs a path");
                    std::process::exit(2);
                }
            },
            "--dump-gauges" => dump_gauges = true,
            "--help" | "-h" => {
                println!("usage: nbl-lint [--root <repo>] [--dump-gauges]");
                return;
            }
            other => {
                eprintln!("nbl-lint: unknown argument `{other}`");
                std::process::exit(2);
            }
        }
    }
    if dump_gauges {
        match gauges::dump_gauges_json(&root) {
            Some(json) => println!("{json}"),
            None => {
                eprintln!(
                    "nbl-lint: could not parse stats_to_json keys under {}",
                    root.display()
                );
                std::process::exit(2);
            }
        }
        return;
    }
    let findings = run_all(&root);
    for f in &findings {
        println!("{}:{}: [{}] {}", f.file, f.line, f.pass, f.msg);
    }
    if findings.is_empty() {
        println!("nbl-lint: clean");
    } else {
        println!("nbl-lint: {} finding(s)", findings.len());
        std::process::exit(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(which: &str) -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(which)
    }

    fn by_pass<'a>(findings: &'a [Finding], pass: &str) -> Vec<&'a Finding> {
        findings.iter().filter(|f| f.pass == pass).collect()
    }

    #[test]
    fn violations_tree_trips_every_pass() {
        let findings = run_all(&fixture("violations"));
        for pass in ["panic", "charge", "guard", "gauge", "unsafe"] {
            assert!(
                !by_pass(&findings, pass).is_empty(),
                "pass `{pass}` caught nothing in fixtures/violations; all: {findings:?}"
            );
        }
    }

    #[test]
    fn violations_tree_details() {
        let findings = run_all(&fixture("violations"));
        // panic: unwrap + expect + panic! + dynamic self-indexing
        assert!(by_pass(&findings, "panic").len() >= 4, "{findings:?}");
        assert!(findings
            .iter()
            .any(|f| f.pass == "panic" && f.file.ends_with("hot_path.rs")));
        // charge: one early-? exit, one never-settled
        let charges = by_pass(&findings, "charge");
        assert_eq!(charges.len(), 2, "{charges:?}");
        // guard: send under a live guard
        assert!(findings
            .iter()
            .any(|f| f.pass == "guard" && f.file.ends_with("guard.rs")));
        // gauge: orphan field + dead baseline floor
        let gauges = by_pass(&findings, "gauge");
        assert!(
            gauges.iter().any(|f| f.file.ends_with("metrics.rs")),
            "{gauges:?}"
        );
        assert!(
            gauges.iter().any(|f| f.file.ends_with("bench_baseline.json")),
            "{gauges:?}"
        );
        // unsafe: bare unsafe impl
        assert!(findings
            .iter()
            .any(|f| f.pass == "unsafe" && f.file.ends_with("ffi.rs")));
    }

    #[test]
    fn clean_tree_passes() {
        let findings = run_all(&fixture("clean"));
        assert!(findings.is_empty(), "expected clean, got: {findings:?}");
    }

    #[test]
    fn dump_gauges_reads_fixture_registry() {
        let json = gauges::dump_gauges_json(&fixture("clean")).expect("clean api.rs parses");
        assert!(json.contains("\"nbl-gauges/v1\""), "{json}");
        assert!(json.contains("\"requests\""), "{json}");
    }
}
