//! The lexical passes over scanned files. Rule catalog and escape
//! hatches are documented in DESIGN.md §Static analysis.

use crate::lexer::ScannedFile;

#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub pass: &'static str,
    pub msg: String,
}

impl Finding {
    fn new(f: &ScannedFile, line0: usize, pass: &'static str, msg: String) -> Finding {
        Finding { file: f.path.clone(), line: line0 + 1, pass, msg }
    }
}

/// Pass 1: hot-path panic audit. In `server/`, `executor/`, `kvcache/`
/// non-test code, flag panicking constructs and self-field indexing
/// with a non-literal index. Escape: `// nbl-lint: allow(panic): why`.
pub fn panic_pass(f: &ScannedFile, out: &mut Vec<Finding>) {
    const TOKENS: &[(&str, &str)] = &[
        (".unwrap()", "unwrap() on the hot path"),
        (".expect(", "expect() on the hot path"),
        ("panic!", "panic! on the hot path"),
        ("unreachable!", "unreachable! on the hot path"),
        ("todo!", "todo! on the hot path"),
        ("unimplemented!", "unimplemented! on the hot path"),
    ];
    for (i, line) in f.masked.iter().enumerate() {
        if f.in_test[i] || f.allowed(i, "panic") {
            continue;
        }
        for (tok, what) in TOKENS {
            if line.contains(tok) {
                out.push(Finding::new(
                    f,
                    i,
                    "panic",
                    format!("{what}; return an Error or annotate `nbl-lint: allow(panic)`"),
                ));
                break;
            }
        }
        if let Some(idx) = self_index_expr(line) {
            out.push(Finding::new(
                f,
                i,
                "panic",
                format!(
                    "self-field indexing `[{idx}]` can panic; use .get()/.get_mut() \
                     or annotate `nbl-lint: allow(panic)`"
                ),
            ));
        }
    }
}

/// Detect `self.<field...>[expr]` with a non-numeric index on one line.
fn self_index_expr(line: &str) -> Option<String> {
    let bytes = line.as_bytes();
    let mut from = 0usize;
    while let Some(p) = line[from..].find("self.") {
        let at = from + p;
        let mut j = at + "self.".len();
        while j < bytes.len()
            && (bytes[j].is_ascii_alphanumeric() || bytes[j] == b'_' || bytes[j] == b'.')
        {
            j += 1;
        }
        while j < bytes.len() && bytes[j] == b' ' {
            j += 1;
        }
        if j < bytes.len() && bytes[j] == b'[' {
            // find the matching close on this line
            let mut depth = 1i32;
            let mut k = j + 1;
            while k < bytes.len() && depth > 0 {
                match bytes[k] {
                    b'[' => depth += 1,
                    b']' => depth -= 1,
                    _ => {}
                }
                k += 1;
            }
            if depth == 0 {
                let inner = line[j + 1..k - 1].trim();
                let literal = !inner.is_empty() && inner.bytes().all(|b| b.is_ascii_digit());
                // `self.x[..]` full-range slicing and literal indexes
                // into fixed arrays can't drift with request state
                if !literal && inner != ".." && !inner.is_empty() {
                    return Some(inner.to_string());
                }
            }
        }
        from = at + "self.".len();
    }
    None
}

/// Pass 2: charge/refund pairing. A `KvPool::try_take` charge must be
/// settled — handed to a refund path (`give_back`), wrapped in an RAII
/// lease (`KvLease`/`KvLeaseOwned`), or explicitly marked with
/// `// nbl-lint: settles(charge): why` at the line that takes
/// ownership of the debit — before any `?` / `return Err` exit.
/// A same-line `?` on the charge itself is fine: `try_take` only
/// debits on success, so the failure exit carries no charge.
pub fn charge_pass(f: &ScannedFile, out: &mut Vec<Finding>) {
    for (start, end) in f.fn_spans() {
        for c in start..=end {
            if f.in_test[c] || !f.masked[c].contains(".try_take(") {
                continue;
            }
            if f.allowed(c, "charge") {
                continue;
            }
            if is_settle(f, c) {
                continue;
            }
            let mut settled = false;
            for j in c + 1..=end {
                if is_settle(f, j) {
                    settled = true;
                    break;
                }
                let l = &f.masked[j];
                if l.contains('?') || l.contains("return Err") {
                    out.push(Finding::new(
                        f,
                        j,
                        "charge",
                        format!(
                            "early exit while the KvPool charge from line {} is \
                             unsettled; refund via give_back/lease or move the \
                             exit before the charge",
                            c + 1
                        ),
                    ));
                    settled = true; // one finding per charge
                    break;
                }
            }
            if !settled {
                out.push(Finding::new(
                    f,
                    c,
                    "charge",
                    "KvPool charge is never settled in this function; wrap it in a \
                     lease or annotate the owning line with `nbl-lint: settles(charge)`"
                        .to_string(),
                ));
            }
        }
    }
}

fn is_settle(f: &ScannedFile, line0: usize) -> bool {
    let l = &f.masked[line0];
    f.marks[line0].settles
        || l.contains("give_back(")
        || l.contains("KvLease")
        || l.contains("KvLeaseOwned")
}

const BLOCKING_TOKENS: &[&str] = &[
    ".send(",
    ".recv(",
    "recv_timeout(",
    "read_line(",
    "write_all(",
    "write_fmt(",
    ".flush(",
    ".accept(",
    ".decode_rows",
    ".prefill(",
    ".prefill_chunk(",
    ".prefill_suffix(",
    ".join(",
    "sleep(",
];

const LOCK_TOKENS: &[&str] = &[".lock()", ".read()", ".write()", "lock_unpoisoned("];

/// Pass 3: no Mutex/RwLock guard live across a blocking call (channel
/// send/recv, TCP I/O, device decode/prefill, joins, sleeps) — the
/// deadlock shape a multi-replica dispatcher would hit first.
pub fn guard_pass(f: &ScannedFile, out: &mut Vec<Finding>) {
    for (start, end) in f.fn_spans() {
        let mut depth = 0i32;
        // (birth depth) of each live guard binding
        let mut guards: Vec<i32> = Vec::new();
        for i in start..=end {
            let l = &f.masked[i];
            let opens: i32 = l.matches('{').count() as i32;
            let closes: i32 = l.matches('}').count() as i32;
            let is_lock = LOCK_TOKENS.iter().any(|t| l.contains(t));
            let blocking = BLOCKING_TOKENS.iter().find(|t| l.contains(**t));
            if !f.in_test[i] && !f.allowed(i, "guard") {
                if let Some(tok) = blocking {
                    if !guards.is_empty() || is_lock {
                        out.push(Finding::new(
                            f,
                            i,
                            "guard",
                            format!(
                                "lock guard held across blocking call `{}`; drop the \
                                 guard (narrow scope / clone out) before blocking",
                                tok.trim_start_matches('.').trim_end_matches('(')
                            ),
                        ));
                    }
                }
            }
            if l.contains("drop(") {
                guards.clear();
            }
            if is_lock && l.contains("let ") && blocking.is_none() {
                guards.push(depth + opens.min(1));
            }
            depth += opens - closes;
            guards.retain(|&birth| depth >= birth);
        }
    }
}

/// Pass 5 (satellite b): `unsafe` is denied crate-wide; each retained
/// impl must carry `#[allow(unsafe_code)]` with a SAFETY note.
pub fn unsafe_pass(f: &ScannedFile, out: &mut Vec<Finding>) {
    for (i, line) in f.masked.iter().enumerate() {
        if f.in_test[i] || !has_word(line, "unsafe") {
            continue;
        }
        let sanctioned = line.contains("#[allow(unsafe_code)]")
            || (i > 0 && f.masked[i - 1].contains("#[allow(unsafe_code)]"))
            || line.contains("#![deny(unsafe_code)]")
            || line.contains("unsafe_code");
        if !sanctioned {
            out.push(Finding::new(
                f,
                i,
                "unsafe",
                "unsafe outside the allowlist; add #[allow(unsafe_code)] with a \
                 SAFETY comment or remove the unsafe block"
                    .to_string(),
            ));
        }
    }
}

fn has_word(line: &str, word: &str) -> bool {
    let bytes = line.as_bytes();
    let mut from = 0usize;
    while let Some(p) = line[from..].find(word) {
        let at = from + p;
        let endb = at + word.len();
        let left_ok =
            at == 0 || !(bytes[at - 1].is_ascii_alphanumeric() || bytes[at - 1] == b'_');
        let right_ok = endb >= bytes.len()
            || !(bytes[endb].is_ascii_alphanumeric() || bytes[endb] == b'_');
        if left_ok && right_ok {
            return true;
        }
        from = endb;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::ScannedFile;

    fn run(pass: fn(&ScannedFile, &mut Vec<Finding>), src: &str) -> Vec<Finding> {
        let f = ScannedFile::scan("t.rs", src);
        let mut out = Vec::new();
        pass(&f, &mut out);
        out
    }

    #[test]
    fn panic_flags_unwrap_not_unwrap_or() {
        let v = run(panic_pass, "fn a() { x.unwrap(); y.unwrap_or(0); }\n");
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].line, 1);
    }

    #[test]
    fn panic_flags_self_indexing_with_dynamic_index() {
        let v = run(panic_pass, "fn a(&mut self) { self.slots[slot].pos = 0; }\n");
        assert_eq!(v.len(), 1);
        let v = run(panic_pass, "fn a(&self) { let x = self.lut[3]; }\n");
        assert!(v.is_empty(), "literal index is fine: {v:?}");
    }

    #[test]
    fn panic_respects_allow() {
        let v = run(
            panic_pass,
            "fn a() {\n    // nbl-lint: allow(panic): invariant\n    x.unwrap();\n}\n",
        );
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn charge_flags_question_mark_before_settle() {
        let src = "fn a(&mut self) -> Result<(), E> {\n    self.pool.try_take(n)?;\n    self.other()?;\n    self.tables.give_back(n);\n    Ok(())\n}\n";
        let v = run(charge_pass, src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].line, 3);
    }

    #[test]
    fn charge_ok_when_lease_wraps_immediately() {
        let src = "fn a(&self) -> Result<KvLease, E> {\n    self.try_take(n)?;\n    Ok(KvLease { pool: self, bytes: n })\n}\n";
        let v = run(charge_pass, src);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn charge_ok_with_settles_mark() {
        let src = "fn a(&mut self) -> Result<(), E> {\n    self.pool.try_take(n)?;\n    // nbl-lint: settles(charge): table owns the debit\n    self.install(n)?;\n    Ok(())\n}\n";
        let v = run(charge_pass, src);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn charge_flags_never_settled() {
        let src = "fn a(&mut self) -> Result<(), E> {\n    self.pool.try_take(n)?;\n    Ok(())\n}\n";
        let v = run(charge_pass, src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn guard_flags_send_under_live_guard() {
        let src = "fn a(&self) {\n    let g = self.state.lock();\n    self.tx.send(g.x);\n}\n";
        let v = run(guard_pass, src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].line, 3);
    }

    #[test]
    fn guard_dies_with_scope() {
        let src = "fn a(&self) {\n    {\n        let g = self.state.lock();\n        use_it(&g);\n    }\n    self.tx.send(1);\n}\n";
        let v = run(guard_pass, src);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn guard_flags_same_line_lock_and_block() {
        let v = run(guard_pass, "fn a(&self) { self.tx.send(self.m.lock().x); }\n");
        assert_eq!(v.len(), 1);
    }

    #[test]
    fn unsafe_requires_allow_attr() {
        let v = run(unsafe_pass, "unsafe impl Send for X {}\n");
        assert_eq!(v.len(), 1);
        let v = run(
            unsafe_pass,
            "#[allow(unsafe_code)] // SAFETY: handle is owned\nunsafe impl Send for X {}\n",
        );
        assert!(v.is_empty(), "{v:?}");
    }
}
