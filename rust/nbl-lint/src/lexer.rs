//! A small Rust source scanner: masks strings, char literals and
//! comments so the passes can pattern-match on code without tripping on
//! doc text, collects `nbl-lint:` control comments, and marks
//! `#[cfg(test)]` regions.
//!
//! This is NOT a parser (syn is not available offline — DESIGN.md §3);
//! the passes are lexical by design, and ci/check_artifacts.py
//! cross-checks the gauge extraction against an independent Python
//! parse so scanner rot fails CI instead of silently passing.

use std::collections::HashSet;

/// Control comments understood by the passes:
///   // nbl-lint: allow(panic): reason          (this or next line)
///   // nbl-lint: settles(charge): reason       (this or next line)
///   // nbl-lint: gauge(key_a, key_b)           (field alias, next line)
#[derive(Debug, Default, Clone)]
pub struct LineMarks {
    pub allows: HashSet<String>,
    pub settles: bool,
    pub gauge_aliases: Vec<String>,
}

#[derive(Debug)]
pub struct ScannedFile {
    /// Path as reported in findings (relative to the scan root).
    pub path: String,
    /// Raw source lines (1-indexed via `line + 1`).
    pub raw: Vec<String>,
    /// Source with strings/chars/comments blanked, line by line.
    pub masked: Vec<String>,
    /// Effective control marks per line (annotations apply to their own
    /// line when it holds code, otherwise to the following line).
    pub marks: Vec<LineMarks>,
    /// True for lines inside a `#[cfg(test)]` item.
    pub in_test: Vec<bool>,
}

impl ScannedFile {
    pub fn scan(path: &str, src: &str) -> ScannedFile {
        let raw: Vec<String> = src.lines().map(|l| l.to_string()).collect();
        let (masked_text, comments) = mask(src);
        let masked: Vec<String> = masked_text.lines().map(|l| l.to_string()).collect();
        let n = raw.len();
        let mut marks = vec![LineMarks::default(); n];
        for (line, body) in comments {
            let Some(m) = parse_mark(&body) else { continue };
            // trailing comment -> same line; standalone comment -> next
            let target = if line < n && !masked[line].trim().is_empty() {
                line
            } else {
                line + 1
            };
            if target < n {
                marks[target].allows.extend(m.allows);
                marks[target].settles |= m.settles;
                marks[target].gauge_aliases.extend(m.gauge_aliases);
            }
        }
        let in_test = test_regions(&masked);
        ScannedFile { path: path.to_string(), raw, masked, marks, in_test }
    }

    pub fn allowed(&self, line: usize, pass: &str) -> bool {
        self.marks.get(line).is_some_and(|m| m.allows.contains(pass))
    }

    /// Line spans (start..=end, 0-indexed) of non-test `fn` bodies.
    pub fn fn_spans(&self) -> Vec<(usize, usize)> {
        let mut spans = Vec::new();
        let mut i = 0usize;
        while i < self.masked.len() {
            if self.in_test[i] || !has_fn_keyword(&self.masked[i]) {
                i += 1;
                continue;
            }
            // find the opening brace (same line or a later one), then
            // the matching close; trait-decl `fn ...;` has none
            let mut depth = 0i32;
            let mut opened = false;
            let mut end = i;
            'outer: for (j, l) in self.masked.iter().enumerate().skip(i) {
                for c in l.chars() {
                    match c {
                        '{' => {
                            depth += 1;
                            opened = true;
                        }
                        '}' => depth -= 1,
                        ';' if !opened => break 'outer,
                        _ => {}
                    }
                }
                if opened && depth <= 0 {
                    end = j;
                    break;
                }
                end = j;
            }
            if opened {
                spans.push((i, end));
                // continue scanning INSIDE the span too? nested fns are
                // rare; skipping keeps one finding per outer function
                i = end + 1;
            } else {
                i += 1;
            }
        }
        spans
    }
}

fn has_fn_keyword(line: &str) -> bool {
    let bytes = line.as_bytes();
    let mut from = 0usize;
    while let Some(p) = line[from..].find("fn ") {
        let at = from + p;
        let boundary = at == 0
            || !(bytes[at - 1].is_ascii_alphanumeric() || bytes[at - 1] == b'_');
        if boundary {
            return true;
        }
        from = at + 3;
    }
    false
}

fn parse_mark(comment: &str) -> Option<LineMarks> {
    let at = comment.find("nbl-lint:")?;
    let rest = comment[at + "nbl-lint:".len()..].trim_start();
    let mut m = LineMarks::default();
    if let Some(args) = rest.strip_prefix("allow(").and_then(paren_args) {
        m.allows = args.into_iter().collect();
    } else if rest.starts_with("settles(") {
        m.settles = true;
    } else if let Some(args) = rest.strip_prefix("gauge(").and_then(paren_args) {
        m.gauge_aliases = args;
    } else {
        return None;
    }
    Some(m)
}

fn paren_args(after_open: &str) -> Option<Vec<String>> {
    let close = after_open.find(')')?;
    Some(
        after_open[..close]
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
    )
}

/// Blank out comments, strings and char literals, returning the masked
/// text plus each line comment's body (for `nbl-lint:` marks).
fn mask(src: &str) -> (String, Vec<(usize, String)>) {
    let chars: Vec<char> = src.chars().collect();
    let mut out = String::with_capacity(src.len());
    let mut comments: Vec<(usize, String)> = Vec::new();
    let mut line = 0usize;
    let mut i = 0usize;
    let push_masked = |out: &mut String, c: char| {
        out.push(if c == '\n' { '\n' } else { ' ' });
    };
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            out.push('\n');
            line += 1;
            i += 1;
        } else if c == '/' && chars.get(i + 1) == Some(&'/') {
            let start = i;
            while i < chars.len() && chars[i] != '\n' {
                push_masked(&mut out, chars[i]);
                i += 1;
            }
            comments.push((line, chars[start..i].iter().collect()));
        } else if c == '/' && chars.get(i + 1) == Some(&'*') {
            let mut depth = 1i32;
            push_masked(&mut out, chars[i]);
            push_masked(&mut out, chars[i + 1]);
            i += 2;
            while i < chars.len() && depth > 0 {
                if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                    depth += 1;
                    push_masked(&mut out, chars[i]);
                    push_masked(&mut out, chars[i + 1]);
                    i += 2;
                } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    push_masked(&mut out, chars[i]);
                    push_masked(&mut out, chars[i + 1]);
                    i += 2;
                } else {
                    if chars[i] == '\n' {
                        line += 1;
                    }
                    push_masked(&mut out, chars[i]);
                    i += 1;
                }
            }
        } else if c == '"' {
            i = mask_string(&chars, i, &mut out, &mut line);
        } else if (c == 'r' || c == 'b') && is_raw_or_byte_string(&chars, i) {
            // r"..", r#".."#, b"..", br".." — skip prefix then the body
            let mut j = i;
            while j < chars.len() && (chars[j] == 'r' || chars[j] == 'b') {
                push_masked(&mut out, chars[j]);
                j += 1;
            }
            let mut hashes = 0usize;
            while chars.get(j) == Some(&'#') {
                hashes += 1;
                push_masked(&mut out, '#');
                j += 1;
            }
            // opening quote
            push_masked(&mut out, '"');
            j += 1;
            if hashes == 0 && chars[i] == 'b' && chars.get(i + 1) != Some(&'"')
                && chars.get(i + 1) != Some(&'r')
            {
                i = j; // defensive; is_raw_or_byte_string should prevent
                continue;
            }
            loop {
                match chars.get(j) {
                    None => break,
                    Some('"') => {
                        let mut k = 0usize;
                        while k < hashes && chars.get(j + 1 + k) == Some(&'#') {
                            k += 1;
                        }
                        if k == hashes {
                            push_masked(&mut out, '"');
                            for _ in 0..hashes {
                                push_masked(&mut out, '#');
                            }
                            j += 1 + hashes;
                            break;
                        }
                        push_masked(&mut out, '"');
                        j += 1;
                    }
                    Some('\\') if hashes == 0 => {
                        // only cooked byte strings escape; raw never
                        push_masked(&mut out, '\\');
                        j += 1;
                        if let Some(&e) = chars.get(j) {
                            if e == '\n' {
                                line += 1;
                            }
                            push_masked(&mut out, e);
                            j += 1;
                        }
                    }
                    Some(&ch) => {
                        if ch == '\n' {
                            line += 1;
                        }
                        push_masked(&mut out, ch);
                        j += 1;
                    }
                }
            }
            i = j;
        } else if c == '\'' && is_char_literal(&chars, i) {
            push_masked(&mut out, '\'');
            i += 1;
            if chars.get(i) == Some(&'\\') {
                push_masked(&mut out, '\\');
                i += 1;
            }
            while i < chars.len() && chars[i] != '\'' {
                push_masked(&mut out, chars[i]);
                i += 1;
            }
            if i < chars.len() {
                push_masked(&mut out, '\'');
                i += 1;
            }
        } else {
            out.push(c);
            i += 1;
        }
    }
    (out, comments)
}

fn is_raw_or_byte_string(chars: &[char], i: usize) -> bool {
    // r" r# b" br" rb — any (r|b)+ then optional #s then a quote, with
    // the previous char not part of an identifier (so `for_bench"` etc.
    // never matches)
    if i > 0 {
        let p = chars[i - 1];
        if p.is_alphanumeric() || p == '_' {
            return false;
        }
    }
    let mut j = i;
    let mut prefix = 0usize;
    while j < chars.len() && (chars[j] == 'r' || chars[j] == 'b') && prefix < 2 {
        j += 1;
        prefix += 1;
    }
    let has_r = chars[i..j].contains(&'r');
    while chars.get(j) == Some(&'#') {
        if !has_r {
            return false;
        }
        j += 1;
    }
    chars.get(j) == Some(&'"')
}

fn is_char_literal(chars: &[char], i: usize) -> bool {
    // 'x' or '\n' etc.; a lone 'a (lifetime) has no closing quote in
    // the next two characters
    match chars.get(i + 1) {
        Some('\\') => true,
        Some(_) => chars.get(i + 2) == Some(&'\''),
        None => false,
    }
}

fn mask_string(chars: &[char], mut i: usize, out: &mut String, line: &mut usize) -> usize {
    out.push(' '); // opening quote
    i += 1;
    while i < chars.len() {
        match chars[i] {
            '"' => {
                out.push(' ');
                return i + 1;
            }
            '\\' => {
                out.push(' ');
                i += 1;
                if i < chars.len() {
                    if chars[i] == '\n' {
                        *line += 1;
                        out.push('\n');
                    } else {
                        out.push(' ');
                    }
                    i += 1;
                }
            }
            '\n' => {
                *line += 1;
                out.push('\n');
                i += 1;
            }
            _ => {
                out.push(' ');
                i += 1;
            }
        }
    }
    i
}

/// Mark every line belonging to a `#[cfg(test)]` item (the attribute
/// line through the close of the item's brace block).
fn test_regions(masked: &[String]) -> Vec<bool> {
    let mut in_test = vec![false; masked.len()];
    let mut i = 0usize;
    while i < masked.len() {
        if !masked[i].contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        let mut depth = 0i32;
        let mut opened = false;
        let mut end = i;
        for (j, l) in masked.iter().enumerate().skip(i) {
            for c in l.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            end = j;
            if opened && depth <= 0 {
                break;
            }
        }
        for t in in_test.iter_mut().take(end + 1).skip(i) {
            *t = true;
        }
        i = end + 1;
    }
    in_test
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_strings_and_comments() {
        let src = "let a = \"unwrap() inside\"; // unwrap() in comment\nlet b = a.unwrap();\n";
        let f = ScannedFile::scan("x.rs", src);
        assert!(!f.masked[0].contains("unwrap"));
        assert!(f.masked[1].contains(".unwrap()"));
    }

    #[test]
    fn masks_raw_strings_and_chars() {
        let src = "let s = r#\"panic!(\"x\")\"#;\nlet c = '\\'';\nlet l: &'static str = \"y\";\n";
        let f = ScannedFile::scan("x.rs", src);
        assert!(!f.masked[0].contains("panic!"));
        assert!(f.masked[2].contains("&'static str"));
    }

    #[test]
    fn allow_marks_attach_to_code_lines() {
        let src = "// nbl-lint: allow(panic): provable\nlet a = x.unwrap();\nlet b = y.unwrap(); // nbl-lint: allow(panic): also fine\n";
        let f = ScannedFile::scan("x.rs", src);
        assert!(f.allowed(1, "panic"));
        assert!(f.allowed(2, "panic"));
        assert!(!f.allowed(0, "panic"));
    }

    #[test]
    fn test_regions_cover_mod_tests() {
        let src = "fn live() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n    fn t() { y.unwrap(); }\n}\nfn live2() {}\n";
        let f = ScannedFile::scan("x.rs", src);
        assert!(!f.in_test[0]);
        assert!(f.in_test[1] && f.in_test[2] && f.in_test[3] && f.in_test[4]);
        assert!(!f.in_test[5]);
    }

    #[test]
    fn fn_spans_cover_bodies() {
        let src = "fn a() {\n    body();\n}\nstruct S;\nfn b() { one_liner(); }\n";
        let f = ScannedFile::scan("x.rs", src);
        let spans = f.fn_spans();
        assert_eq!(spans, vec![(0, 2), (4, 4)]);
    }
}
