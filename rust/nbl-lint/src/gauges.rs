//! Pass 4: gauge coherence. The stats endpoint (`stats_to_json` in
//! rust/src/server/api.rs) is the canonical metric-name registry; this
//! pass checks
//!
//!   A. every `SchedulerGauges` field in rust/src/server/metrics.rs
//!      surfaces there under its own name, or carries a
//!      `// nbl-lint: gauge(alias, ...)` mark naming the derived keys
//!      it feeds (e.g. `kv_in_use` -> `kv_in_use_bytes`);
//!   B. every floored key in ci/bench_baseline.json names a metric the
//!      mapped nbl-bench emitter actually writes, so a renamed emitter
//!      string can no longer silently turn a CI floor into a no-op
//!      (the PR 5/6 string-drift bug class);
//!   C. the ISSUE 8 observability key families (TTFT attribution,
//!      flight-recorder counters, timing-retention counters, per-phase
//!      gauges) are all present — a rename or revert in stats_to_json
//!      fails here instead of silently dropping a dashboard column.
//!
//! `nbl-lint --dump-gauges` prints the canonical registry as JSON for
//! ci/check_artifacts.py to cross-check with an independent parser.

use crate::lexer::ScannedFile;
use crate::passes::Finding;
use std::path::Path;

const API: &str = "rust/src/server/api.rs";
const METRICS: &str = "rust/src/server/metrics.rs";
const BASELINE: &str = "ci/bench_baseline.json";

/// Stats keys the observability surface contracts to expose (mirrored
/// by ci/check_artifacts.py REQUIRED_OBSERVABILITY_KEYS — keep in
/// sync): per-request TTFT attribution percentiles, flight-recorder
/// ring counters, bounded-retention counters, and per-phase gauges.
const REQUIRED_OBSERVABILITY_KEYS: &[&str] = &[
    "mean_queue_ms",
    "p50_queue_ms",
    "p95_queue_ms",
    "p99_queue_ms",
    "mean_prefill_ms",
    "p50_prefill_ms",
    "p95_prefill_ms",
    "p99_prefill_ms",
    "mean_stall_ms",
    "p50_stall_ms",
    "p95_stall_ms",
    "p99_stall_ms",
    "mean_park_ms",
    "p50_park_ms",
    "p95_park_ms",
    "p99_park_ms",
    "timings_retained",
    "timings_dropped",
    "timings_capacity",
    "trace_events",
    "trace_dropped",
    "trace_capacity",
    "phase_intake_ms",
    "phase_admission_ms",
    "phase_chunked_ms",
    "phase_observe_ms",
    "phase_decode_ms",
    // streaming front end (DESIGN.md §Streaming front end): request
    // teardown counters, fair-queue occupancy, and deadline SLOs
    "cancelled",
    "expired",
    "shed",
    "tenants_active",
    "goodput_tok_s",
    "slo_attainment",
];

/// Map a bench name from a dotted baseline key to its emitter source.
fn emitter_for(bench: &str) -> Option<&'static str> {
    if bench.starts_with("serve_bench") {
        Some("examples/serve_bench.rs")
    } else if bench == "bench_kv" {
        Some("rust/benches/bench_kv.rs")
    } else {
        None
    }
}

/// Keys emitted by `stats_to_json`, in source order.
pub fn stats_keys(root: &Path) -> Option<Vec<String>> {
    let src = std::fs::read_to_string(root.join(API)).ok()?;
    let f = ScannedFile::scan(API, &src);
    let span = f
        .fn_spans()
        .into_iter()
        .find(|&(s, _)| f.masked[s].contains("stats_to_json"))?;
    let mut keys = Vec::new();
    for raw in &f.raw[span.0..=span.1] {
        let mut rest = raw.as_str();
        while let Some(p) = rest.find("(\"") {
            rest = &rest[p + 2..];
            if let Some(q) = rest.find('"') {
                if rest[q + 1..].starts_with(',') {
                    keys.push(rest[..q].to_string());
                }
                rest = &rest[q + 1..];
            } else {
                break;
            }
        }
    }
    Some(keys)
}

pub fn dump_gauges_json(root: &Path) -> Option<String> {
    let keys = stats_keys(root)?;
    let quoted: Vec<String> = keys.iter().map(|k| format!("\"{k}\"")).collect();
    Some(format!(
        "{{\"schema\": \"nbl-gauges/v1\", \"stats_keys\": [{}]}}",
        quoted.join(", ")
    ))
}

/// `SchedulerGauges` struct fields with their 0-based line and any
/// `gauge(...)` alias marks.
fn gauge_fields(f: &ScannedFile) -> Vec<(String, usize, Vec<String>)> {
    let Some(start) = f
        .masked
        .iter()
        .position(|l| l.contains("struct SchedulerGauges"))
    else {
        return Vec::new();
    };
    let mut fields = Vec::new();
    let mut depth = 0i32;
    let mut opened = false;
    for i in start..f.masked.len() {
        let l = &f.masked[i];
        if opened && depth == 1 {
            let t = l.trim();
            let decl = t.strip_prefix("pub ").unwrap_or(t);
            if let Some(colon) = decl.find(':') {
                let name = decl[..colon].trim();
                if !name.is_empty()
                    && name.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'_')
                {
                    fields.push((name.to_string(), i, f.marks[i].gauge_aliases.clone()));
                }
            }
        }
        for c in l.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if opened && depth <= 0 {
            break;
        }
    }
    fields
}

/// Floored (baseline > 0) dotted keys from ci/bench_baseline.json with
/// their 0-based line numbers. Line-oriented parse of our own format:
/// `"bench.metric": {"baseline": N, ...}`.
fn floored_baseline_keys(text: &str) -> Vec<(String, usize)> {
    let mut keys = Vec::new();
    let mut in_metrics = false;
    for (i, line) in text.lines().enumerate() {
        if line.contains("\"metrics\"") {
            in_metrics = true;
            continue;
        }
        if !in_metrics {
            continue;
        }
        let t = line.trim();
        let Some(rest) = t.strip_prefix('"') else { continue };
        let Some(q) = rest.find('"') else { continue };
        let key = &rest[..q];
        let Some(bp) = rest.find("\"baseline\":") else { continue };
        let num = rest[bp + "\"baseline\":".len()..]
            .trim_start()
            .chars()
            .take_while(|c| c.is_ascii_digit() || *c == '.' || *c == '-')
            .collect::<String>();
        let floored = num.parse::<f64>().map(|v| v > 0.0).unwrap_or(false);
        if floored {
            keys.push((key.to_string(), i));
        }
    }
    keys
}

pub fn gauge_pass(root: &Path, out: &mut Vec<Finding>) {
    let Some(keys) = stats_keys(root) else {
        // no api.rs (bare fixture tree) -> nothing to check against
        return;
    };
    if keys.is_empty() {
        out.push(Finding {
            file: API.to_string(),
            line: 1,
            pass: "gauge",
            msg: "stats_to_json found but no (\"key\", ...) entries parsed; \
                  lint scanner and endpoint have drifted"
                .to_string(),
        });
        return;
    }

    // A: every gauge field surfaces on the stats endpoint
    if let Ok(src) = std::fs::read_to_string(root.join(METRICS)) {
        let f = ScannedFile::scan(METRICS, &src);
        for (name, line0, aliases) in gauge_fields(&f) {
            if keys.iter().any(|k| k == &name) {
                continue;
            }
            if !aliases.is_empty() {
                if let Some(bad) = aliases.iter().find(|a| !keys.contains(a)) {
                    out.push(Finding {
                        file: METRICS.to_string(),
                        line: line0 + 1,
                        pass: "gauge",
                        msg: format!(
                            "gauge alias `{bad}` for field `{name}` is not a \
                             stats endpoint key"
                        ),
                    });
                }
                continue;
            }
            out.push(Finding {
                file: METRICS.to_string(),
                line: line0 + 1,
                pass: "gauge",
                msg: format!(
                    "SchedulerGauges field `{name}` never surfaces on the stats \
                     endpoint; export it in stats_to_json or mark the derived \
                     keys with `nbl-lint: gauge(key, ...)`"
                ),
            });
        }
    }

    // C: the observability surface keeps its contracted key families
    for want in REQUIRED_OBSERVABILITY_KEYS {
        if !keys.iter().any(|k| k == want) {
            out.push(Finding {
                file: API.to_string(),
                line: 1,
                pass: "gauge",
                msg: format!(
                    "stats_to_json no longer emits required observability key \
                     `{want}` (TTFT attribution / trace / retention / phase \
                     surface, DESIGN.md §Observability)"
                ),
            });
        }
    }

    // B: floored baseline keys name metrics their emitter still writes
    let Ok(baseline) = std::fs::read_to_string(root.join(BASELINE)) else {
        return;
    };
    for (dotted, line0) in floored_baseline_keys(&baseline) {
        let (bench, _, metric) = {
            let mut it = dotted.splitn(2, '.');
            let b = it.next().unwrap_or("");
            let m = it.next().unwrap_or("");
            (b, ".", m)
        };
        let Some(emitter) = emitter_for(bench) else {
            out.push(Finding {
                file: BASELINE.to_string(),
                line: line0 + 1,
                pass: "gauge",
                msg: format!(
                    "floored key `{dotted}` has no known emitter mapping; teach \
                     nbl-lint (emitter_for) about this bench"
                ),
            });
            continue;
        };
        if metric.is_empty() {
            out.push(Finding {
                file: BASELINE.to_string(),
                line: line0 + 1,
                pass: "gauge",
                msg: format!("floored key `{dotted}` is not of the form bench.metric"),
            });
            continue;
        }
        let Ok(src) = std::fs::read_to_string(root.join(emitter)) else {
            out.push(Finding {
                file: BASELINE.to_string(),
                line: line0 + 1,
                pass: "gauge",
                msg: format!("emitter {emitter} for floored key `{dotted}` is missing"),
            });
            continue;
        };
        if !src.contains(&format!("\"{metric}\"")) {
            out.push(Finding {
                file: BASELINE.to_string(),
                line: line0 + 1,
                pass: "gauge",
                msg: format!(
                    "floored key `{dotted}`: emitter {emitter} never writes \
                     \"{metric}\" — the CI floor is a silent no-op"
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn floored_keys_skip_record_only() {
        let text = "{\n \"metrics\": {\n  \"a.x\": {\"baseline\": 10.0, \"min_ratio\": 0.8},\n  \"a.y\": {\"baseline\": 0.0, \"min_ratio\": 0.8}\n }\n}\n";
        let keys = floored_baseline_keys(text);
        assert_eq!(keys.len(), 1);
        assert_eq!(keys[0].0, "a.x");
    }

    #[test]
    fn required_observability_keys_are_distinct() {
        // the contract list is consumed as a set diff against the parsed
        // endpoint keys; a duplicate would mask a genuinely missing key
        let mut seen = std::collections::BTreeSet::new();
        for k in REQUIRED_OBSERVABILITY_KEYS {
            assert!(seen.insert(*k), "duplicate required key {k}");
        }
        assert!(seen.len() >= 33);
    }

    #[test]
    fn gauge_fields_pick_up_aliases() {
        let src = "pub struct SchedulerGauges {\n    pub iterations: u64,\n    // nbl-lint: gauge(kv_in_use_bytes)\n    pub kv_in_use: u64,\n}\n";
        let f = ScannedFile::scan("m.rs", src);
        let fields = gauge_fields(&f);
        assert_eq!(fields.len(), 2);
        assert_eq!(fields[1].0, "kv_in_use");
        assert_eq!(fields[1].2, vec!["kv_in_use_bytes".to_string()]);
    }
}
