//! Fixture emitter: writes "tok_s", matching the floored baseline key.

fn main() {
    let tok_s = 1.0;
    emit_metric("tok_s", tok_s);
}
