//! Fixture: sanctioned unsafe — `#[allow(unsafe_code)]` with a SAFETY
//! note, the shape the `unsafe` pass must accept.

pub struct Engine {
    handle: *mut u8,
}

// SAFETY: the handle is owned exclusively by Engine and the runtime
// serializes every call through a single worker thread.
#[allow(unsafe_code)]
unsafe impl Send for Engine {}
#[allow(unsafe_code)]
unsafe impl Sync for Engine {}
