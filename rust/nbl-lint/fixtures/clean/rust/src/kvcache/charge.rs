//! Fixture: settled KvPool charges the `charge` pass must accept — a
//! `settles(charge)` mark on the line that takes ownership of the
//! debit, and an RAII lease wrapping the charge immediately.

impl Paged {
    pub fn attach(&mut self, slot: usize, bytes: usize) -> Result<(), Error> {
        self.pool.try_take(bytes)?;
        // nbl-lint: settles(charge): the table entry owns the debit; release() refunds it
        self.tables.push((slot, bytes));
        Ok(())
    }

    pub fn reserve(&self, bytes: usize) -> Result<KvLease<'_>, Error> {
        self.pool.try_take(bytes)?;
        Ok(KvLease { pool: &self.pool, bytes })
    }
}
