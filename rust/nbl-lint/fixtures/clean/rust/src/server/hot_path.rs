//! Fixture: hot-path code the `panic` pass must accept — errors are
//! returned, indexing goes through .get_mut, and the one provable
//! unwrap is annotated.

pub struct Worker {
    slots: Vec<u32>,
}

impl Worker {
    pub fn step(&mut self, slot: usize) -> Result<u32, String> {
        let v = self.pending().ok_or_else(|| "no pending value".to_string())?;
        if let Some(cell) = self.slots.get_mut(slot) {
            *cell = v;
        }
        // nbl-lint: allow(panic): slots is non-empty whenever pending() is Some
        let first = self.slots.first().unwrap();
        Ok(*first)
    }

    fn pending(&self) -> Option<u32> {
        self.slots.first().copied()
    }
}
