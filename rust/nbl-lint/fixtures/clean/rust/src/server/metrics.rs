//! Fixture: every field either matches a stats key exactly or names
//! its derived stats key with a gauge(...) mark.

pub struct SchedulerGauges {
    pub requests: u64,
    pub iterations: u64,
    // nbl-lint: gauge(kv_in_use_bytes)
    pub kv_in_use: u64,
    pub replicas: usize,
}
