//! Fixture: a stats endpoint covering every gauge (directly or via the
//! derived key named by the field's gauge(...) mark) AND the full
//! contracted observability surface (REQUIRED_OBSERVABILITY_KEYS in
//! src/gauges.rs — check C must stay silent on this tree).

pub fn stats_to_json(s: &Summary) -> String {
    let pairs = [
        ("requests", s.requests),
        ("iterations", s.iterations),
        ("kv_in_use_bytes", s.kv_in_use),
        // TTFT attribution percentiles
        ("mean_queue_ms", s.mean_queue_ms),
        ("p50_queue_ms", s.p50_queue_ms),
        ("p95_queue_ms", s.p95_queue_ms),
        ("p99_queue_ms", s.p99_queue_ms),
        ("mean_prefill_ms", s.mean_prefill_ms),
        ("p50_prefill_ms", s.p50_prefill_ms),
        ("p95_prefill_ms", s.p95_prefill_ms),
        ("p99_prefill_ms", s.p99_prefill_ms),
        ("mean_stall_ms", s.mean_stall_ms),
        ("p50_stall_ms", s.p50_stall_ms),
        ("p95_stall_ms", s.p95_stall_ms),
        ("p99_stall_ms", s.p99_stall_ms),
        ("mean_park_ms", s.mean_park_ms),
        ("p50_park_ms", s.p50_park_ms),
        ("p95_park_ms", s.p95_park_ms),
        ("p99_park_ms", s.p99_park_ms),
        // bounded-retention counters
        ("timings_retained", s.timings_retained),
        ("timings_dropped", s.timings_dropped),
        ("timings_capacity", s.timings_capacity),
        // flight-recorder ring counters
        ("trace_events", s.trace_events),
        ("trace_dropped", s.trace_dropped),
        ("trace_capacity", s.trace_capacity),
        // per-phase worker gauges
        ("phase_intake_ms", s.phase_intake_ms),
        ("phase_admission_ms", s.phase_admission_ms),
        ("phase_chunked_ms", s.phase_chunked_ms),
        ("phase_observe_ms", s.phase_observe_ms),
        ("phase_decode_ms", s.phase_decode_ms),
        // streaming front end: teardown counters, fair-queue occupancy,
        // deadline SLOs
        ("cancelled", s.cancelled),
        ("expired", s.expired),
        ("shed", s.shed),
        ("tenants_active", s.tenants_active),
        ("goodput_tok_s", s.goodput_tok_s),
        ("slo_attainment", s.slo_attainment),
        // data-parallel gauge lanes contributing to the rollup
        ("replicas", s.replicas),
    ];
    render(&pairs)
}
