//! Fixture: a stats endpoint covering every gauge (directly or via the
//! derived key named by the field's gauge(...) mark).

pub fn stats_to_json(s: &Summary) -> String {
    let pairs = [
        ("requests", s.requests),
        ("iterations", s.iterations),
        ("kv_in_use_bytes", s.kv_in_use),
    ];
    render(&pairs)
}
