//! Fixture: the guard is scoped out before the channel send, so the
//! `guard` pass must accept this.

pub struct Publisher {
    inner: std::sync::Mutex<Stats>,
    tx: std::sync::mpsc::Sender<Snapshot>,
}

impl Publisher {
    pub fn publish(&self) {
        let snapshot = {
            let stats = self.inner.lock();
            stats.snapshot()
        };
        self.tx.send(snapshot);
    }
}
