//! Fixture: a Mutex guard held across a channel send — the deadlock
//! shape the `guard` pass exists for.

pub struct Publisher {
    inner: std::sync::Mutex<Stats>,
    tx: std::sync::mpsc::Sender<Snapshot>,
}

impl Publisher {
    pub fn publish(&self) {
        let stats = self.inner.lock();
        self.tx.send(stats.snapshot());
    }
}
