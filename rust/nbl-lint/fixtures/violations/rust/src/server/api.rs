//! Fixture: a stats endpoint missing a gauge the metrics struct carries.

pub fn stats_to_json(s: &Summary) -> String {
    let pairs = [
        ("requests", s.requests),
        ("iterations", s.iterations),
    ];
    render(&pairs)
}
