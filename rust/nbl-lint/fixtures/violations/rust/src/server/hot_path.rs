//! Fixture: every construct the `panic` pass must flag on the hot path.

pub struct Worker {
    slots: Vec<u32>,
}

impl Worker {
    pub fn step(&mut self, slot: usize) -> u32 {
        let v = self.pending().unwrap();
        let w = self.pending().expect("always set");
        if v == 0 {
            panic!("zero step");
        }
        self.slots[slot] = w;
        v
    }

    fn pending(&self) -> Option<u32> {
        self.slots.first().copied()
    }
}
