//! Fixture: `orphan_gauge` never surfaces on the stats endpoint and
//! has no `gauge(...)` alias mark.

pub struct SchedulerGauges {
    pub requests: u64,
    pub orphan_gauge: u64,
}
