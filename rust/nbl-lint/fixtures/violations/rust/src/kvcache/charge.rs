//! Fixture: KvPool charges the `charge` pass must flag — an early `?`
//! exit while the debit is live, and a charge never settled at all.

impl Paged {
    pub fn attach(&mut self, slot: usize, bytes: usize) -> Result<(), Error> {
        self.pool.try_take(bytes)?;
        self.ensure_frames(slot)?;
        self.tables.push((slot, bytes));
        Ok(())
    }

    pub fn grow(&mut self, bytes: usize) -> Result<(), Error> {
        self.pool.try_take(bytes)?;
        Ok(())
    }
}
