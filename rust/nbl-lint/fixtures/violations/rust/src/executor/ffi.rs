//! Fixture: bare `unsafe` with no `#[allow(unsafe_code)]` escape.

pub struct Engine {
    handle: *mut u8,
}

unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}
