//! Fixture emitter: writes "tok_s" only, so a floored
//! `serve_bench_fixture.missing_metric` baseline key is a dead gate.

fn main() {
    let tok_s = 1.0;
    emit_metric("tok_s", tok_s);
}
